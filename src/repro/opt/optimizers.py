"""Optimizers and LR schedules, built from scratch (no optax in this env).

An ``Optimizer`` is an (init, update) pair over pytrees; ``update`` consumes
the *gradient estimate* (first- or zeroth-order — the paper's point is that
the update rule doesn't care) and returns parameter deltas.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable  # (grads, state, params, t) -> (deltas, state)
    # introspection for fused update paths (e.g. the flat engine's in-kernel
    # SGD commit): ``kind`` names the update rule, ``hyper`` carries the
    # hyperparameters a fused implementation needs ('schedule', 'momentum',
    # ...).  Defaults keep hand-rolled Optimizers working unchanged — an
    # unknown kind simply means "no fused path; use update + apply_deltas".
    kind: str = "custom"
    hyper: dict | None = None


def _tree_zeros_like(tree):
    return jax.tree.map(lambda t: jnp.zeros_like(t, dtype=jnp.float32), tree)


# --------------------------------------------------------------------------- #
# schedules
# --------------------------------------------------------------------------- #
def const_schedule(lr: float):
    return lambda t: jnp.asarray(lr, jnp.float32)


def invsqrt_schedule(lr: float, warmup: int = 0):
    def f(t):
        s = jnp.sqrt(jnp.asarray(warmup + 1, jnp.float32) / (t + warmup + 1))
        return jnp.asarray(lr, jnp.float32) * s
    return f


def cosine_schedule(lr: float, total: int, warmup: int = 0, floor: float = 0.1):
    def f(t):
        t = jnp.asarray(t, jnp.float32)
        warm = jnp.minimum(t / jnp.maximum(warmup, 1), 1.0)
        frac = jnp.clip((t - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.asarray(lr, jnp.float32) * jnp.where(t < warmup, warm, cos)
    return f


def theorem_lr(B: int, m: int, N: int, L: float = 1.0) -> float:
    """Theorem 1 step size: alpha_t = sqrt(B*m) / (L*sqrt(N))."""
    return math.sqrt(B * m) / (L * math.sqrt(N))


# --------------------------------------------------------------------------- #
# SGD (+ momentum)
# --------------------------------------------------------------------------- #
def sgd(schedule, momentum: float = 0.0):
    def init(params):
        return _tree_zeros_like(params) if momentum else ()

    def update(grads, state, params, t):
        lr = schedule(t)
        if momentum:
            state = jax.tree.map(
                lambda v, g: momentum * v + g.astype(jnp.float32), state, grads
            )
            deltas = jax.tree.map(lambda v: -lr * v, state)
        else:
            deltas = jax.tree.map(lambda g: -lr * g.astype(jnp.float32), grads)
        return deltas, state

    return Optimizer(init, update, kind="sgd",
                     hyper={"schedule": schedule, "momentum": float(momentum)})


# --------------------------------------------------------------------------- #
# Adam
# --------------------------------------------------------------------------- #
def adam(schedule, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8):
    def init(params):
        return (_tree_zeros_like(params), _tree_zeros_like(params))

    def update(grads, state, params, t):
        mu, nu = state
        tf = jnp.asarray(t + 1, jnp.float32)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), mu, grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)), nu, grads
        )
        bc1 = 1 - b1 ** tf
        bc2 = 1 - b2 ** tf
        lr = schedule(t)
        deltas = jax.tree.map(
            lambda m, v: -lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps), mu, nu
        )
        return deltas, (mu, nu)

    return Optimizer(init, update, kind="adam",
                     hyper={"schedule": schedule, "b1": b1, "b2": b2, "eps": eps})


def apply_deltas(params, deltas):
    return jax.tree.map(lambda p, d: (p.astype(jnp.float32) + d).astype(p.dtype), params, deltas)
