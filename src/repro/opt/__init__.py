from repro.opt.optimizers import (  # noqa: F401
    Optimizer,
    adam,
    const_schedule,
    cosine_schedule,
    invsqrt_schedule,
    sgd,
    theorem_lr,
)
