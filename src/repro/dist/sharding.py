"""Sharding-spec contract for the whole system (train, dry-run, serving).

One module owns every PartitionSpec decision so the FO step, the ZO step,
the data pipeline, and the serving path all agree on where tensors live:

* **worker axes** — the paper's m workers are the ``("pod", "data")`` mesh
  axes (whichever exist).  The ZO step runs *manual* (shard_map) over them;
  the FO step leaves them to GSPMD data parallelism.  Param specs therefore
  never name a worker axis — except under ``cfg.fsdp``, where the ``data``
  axis additionally shards weights (ZeRO-style) and the manual worker axis
  collapses to ``pod`` (see ``core.distributed.make_zo_step``).
* **model axis** — tensor parallelism: column-parallel projections shard
  their output dim, row-parallel projections their input dim (Megatron
  convention), expert FFNs shard the hidden dim (``moe_sharding='tensor'``)
  or the expert dim (``'expert'``).
* Every rule is divisibility-guarded: a dim that doesn't divide the axis
  size is replicated rather than producing an unshardable program, so the
  same code drives a 512-chip pod and a 1x1 CPU test mesh.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, PartitionSpec as P
from jax.tree_util import DictKey, tree_map_with_path

WORKER_AXIS_ORDER = ("pod", "data")

# column-parallel weights: shard the *last* dim over the model axis
_COL_PARALLEL = {"wq", "wk", "wv", "wg", "wu", "in_proj", "dt_w", "head"}
# row-parallel weights: shard dim -2 (the contraction dim) over the model axis
_ROW_PARALLEL = {"wo", "wd", "out_proj", "x_proj", "A_log"}
# never sharded on the model axis (tiny, or consumed elementwise everywhere)
_REPLICATED = {"router", "conv_w", "conv_b", "dt_b", "D", "scale", "bias",
               "q_norm", "k_norm", "attn_out_scale", "mamba_out_scale"}


def worker_axes(mesh: Mesh) -> Tuple[str, ...]:
    """The manual worker axes: ``("pod", "data")`` ∩ mesh, in that order."""
    return tuple(a for a in WORKER_AXIS_ORDER if a in mesh.shape)


def n_workers(mesh: Mesh) -> int:
    """m — the paper's worker count — for this mesh."""
    n = 1
    for a in worker_axes(mesh):
        n *= mesh.shape[a]
    return n


def _axis_size(mesh: Mesh, axis: str) -> int:
    return mesh.shape.get(axis, 1)


def _path_names(path) -> Tuple[str, ...]:
    return tuple(str(k.key) for k in path if isinstance(k, DictKey))


def _leaf_spec(cfg, mesh: Mesh, names: Tuple[str, ...], shape) -> P:
    """Spec for one parameter leaf, identified by its dict path."""
    name = names[-1] if names else ""
    parent = names[-2] if len(names) > 1 else ""
    stacked = bool(names) and names[0] == "layers"   # leading (L, ...) dim
    off = 1 if stacked else 0
    ndim = len(shape)
    parts: list = [None] * ndim
    ms = _axis_size(mesh, "model")
    ds = _axis_size(mesh, "data")
    fsdp = bool(getattr(cfg, "fsdp", False)) and "data" in mesh.shape

    def put(dim: int, axis: str, size: int) -> bool:
        if 0 <= dim < ndim and parts[dim] is None and shape[dim] % size == 0:
            parts[dim] = axis
            return True
        return False

    # --- model axis: tensor parallelism --------------------------------------
    if "model" in mesh.shape and name not in _REPLICATED and ndim - off >= 2:
        is_expert = parent == "moe" and name in ("wg", "wu", "wd")
        if is_expert and getattr(cfg, "moe_sharding", "tensor") == "expert":
            put(off, "model", ms)                    # expert-parallel: E dim
        elif name == "embed":
            put(ndim - 2, "model", ms)               # vocab rows over model
        elif name in _COL_PARALLEL:
            put(ndim - 1, "model", ms)
        elif name in _ROW_PARALLEL:
            put(ndim - 2, "model", ms)

    # --- data axis: ZeRO/FSDP weight sharding (cfg.fsdp only) ----------------
    if fsdp and ndim - off >= 1 and name != "router":
        if parent == "moe" and name in ("wg", "wu", "wd"):
            # expert dim over data — must match moe._expert_spec's dispatch
            # constraint or the (E, C, ...) tensors fight the weights
            put(off, "data", ds)
        else:
            # largest still-unsharded dim (ties -> earliest), vectors included
            order = sorted(range(off, ndim), key=lambda i: (-shape[i], i))
            for dim in order:
                if put(dim, "data", ds):
                    break

    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def param_specs(cfg, params: Any, mesh: Mesh) -> Any:
    """PartitionSpec tree for a model param tree (works on ShapeDtypeStructs).

    Only names *auto* axes: ``model`` always, ``data`` additionally when
    ``cfg.fsdp`` — never ``pod``.  The ZO step relies on this: inside its
    manual (worker-axes) shard_map these same specs constrain the hashed
    direction leaves without referencing a manual axis.
    """
    return tree_map_with_path(
        lambda path, x: _leaf_spec(cfg, mesh, _path_names(path), x.shape),
        params,
    )


def batch_specs(mesh: Mesh, batch: Any) -> Any:
    """Shard every batch leaf's leading dim over the worker axes.

    Leaves whose leading dim doesn't divide the worker count (or 0-d leaves)
    are replicated — e.g. a scalar position index in a decode batch.
    """
    wa = worker_axes(mesh)
    m = n_workers(mesh)

    def spec(x) -> P:
        shape = getattr(x, "shape", ())
        if not wa or not shape or shape[0] % m:
            return P()
        return P(wa)

    return jax.tree.map(spec, batch)


def cache_specs(cfg, mesh: Mesh, caches: Any, seq_sharded: bool = False) -> Any:
    """Decode/prefill cache shardings (stacked per-layer pytrees).

    * ``k``/``v`` (L, B, S, KV, hd): batch over the worker axes; the kv-head
      dim over ``model``, falling back to head_dim when KV doesn't divide
      (GQA archs with few kv heads on a wide model axis).
    * ``conv`` (L, B, K-1, di) / ``ssm`` (L, B, di, n): batch over workers,
      d_inner over ``model``.
    * ``seq_sharded`` (long_500k, batch=1): the attention cache *sequence*
      dim carries the worker axes instead of batch.
    """
    wa = worker_axes(mesh)
    m = n_workers(mesh)
    ms = _axis_size(mesh, "model")

    def spec(path, x) -> P:
        names = _path_names(path)
        name = names[-1] if names else ""
        shape = x.shape
        parts: list = [None] * len(shape)
        if name in ("k", "v") and len(shape) == 5:
            L, B, S, KV, hd = shape
            if seq_sharded:
                if wa and S % m == 0:
                    parts[2] = wa
            elif wa and B % m == 0:
                parts[1] = wa
            if "model" in mesh.shape:
                if KV % ms == 0 and ms > 1:
                    parts[3] = "model"
                elif hd % ms == 0:
                    parts[4] = "model"
        elif name == "conv" and len(shape) == 4:
            if wa and not seq_sharded and shape[1] % m == 0:
                parts[1] = wa
            if "model" in mesh.shape and shape[3] % ms == 0:
                parts[3] = "model"
        elif name == "ssm" and len(shape) == 4:
            if wa and not seq_sharded and shape[1] % m == 0:
                parts[1] = wa
            if "model" in mesh.shape and shape[2] % ms == 0:
                parts[2] = "model"
        while parts and parts[-1] is None:
            parts.pop()
        return P(*parts)

    return tree_map_with_path(spec, caches)


def named(mesh: Mesh, spec_tree: Any) -> Any:
    """Map a PartitionSpec tree to NamedShardings on ``mesh``."""
    from jax.sharding import NamedSharding
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
