"""repro.dist — sharding specs + instrumented, compression-aware collectives.

The distributed substrate every layer builds on:
  * ``sharding`` — the single source of truth for PartitionSpecs (worker
    axes, tensor/FSDP param specs, batch and serving-cache specs).
  * ``collectives`` — jax.lax collective wrappers + the ``CommLedger`` that
    measures the paper's Table-1 communication load in bytes.
  * ``compress`` — QSGD / signSGD / top-k codecs hookable onto the FO
    all-reduce, with wire-byte estimates fed to the ledger.
"""
from repro.dist.collectives import (  # noqa: F401
    CommLedger,
    all_gather,
    note_all_reduce,
    pmean,
    psum,
)
from repro.dist.compress import (  # noqa: F401
    Compressor,
    compress_tree,
    get_compressor,
    qsgd,
    signsgd,
    topk,
)
from repro.dist.sharding import (  # noqa: F401
    batch_specs,
    cache_specs,
    n_workers,
    param_specs,
    worker_axes,
)
