"""Gradient codecs for the FO all-reduce: QSGD, signSGD, top-k.

Each codec is an (encode, decode) pair over flat fp32 vectors plus a
bytes-on-the-wire estimate that feeds the ``CommLedger`` — so a compressed
FO step books its *actual* wire cost instead of 4*d (QSGD: Alistarh et al.
2017; signSGD: Bernstein et al. 2018; top-k: Aji & Heafield 2017).

The distributed step applies ``decode(encode(g))`` inside the jitted program
(simulating what every worker would receive after a compressed all-reduce)
and books ``nbytes(d)`` in place of the dense gradient's bytes.  Encoding is
unbiased where the original scheme is (QSGD's stochastic rounding uses a
fold-in of the step counter, so the program stays a pure function of t).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Compressor:
    """encode: (flat fp32, key) -> code pytree; decode: code -> flat fp32."""
    name: str
    encode: Callable[[jax.Array, jax.Array], Any]
    decode: Callable[[Any], jax.Array]
    nbytes: Callable[[int], int]          # d -> wire bytes per worker


# --------------------------------------------------------------------------- #
# QSGD — s-level stochastic quantization
# --------------------------------------------------------------------------- #
def qsgd(s: int = 4) -> Compressor:
    bits = max(1, math.ceil(math.log2(s + 1))) + 1   # level bits + sign bit

    def encode(g: jax.Array, key) -> Tuple[jax.Array, jax.Array, jax.Array]:
        norm = jnp.linalg.norm(g) + 1e-30
        level = jnp.abs(g) / norm * s
        lower = jnp.floor(level)
        bump = jax.random.bernoulli(key, level - lower)
        q = (lower + bump).astype(jnp.int8 if s < 127 else jnp.int32)
        return norm, jnp.sign(g).astype(jnp.int8), q

    def decode(code) -> jax.Array:
        norm, sign, q = code
        return sign.astype(jnp.float32) * norm * q.astype(jnp.float32) / s

    return Compressor(
        f"qsgd{s}", encode, decode,
        nbytes=lambda d: 4 + (d * bits + 7) // 8,
    )


# --------------------------------------------------------------------------- #
# signSGD — 1 bit per coordinate + one scale
# --------------------------------------------------------------------------- #
def signsgd() -> Compressor:
    def encode(g: jax.Array, key) -> Tuple[jax.Array, jax.Array]:
        return jnp.mean(jnp.abs(g)), jnp.sign(g).astype(jnp.int8)

    def decode(code) -> jax.Array:
        scale, sign = code
        return scale * sign.astype(jnp.float32)

    return Compressor("signsgd", encode, decode,
                      nbytes=lambda d: 4 + (d + 7) // 8)


# --------------------------------------------------------------------------- #
# top-k — k (index, value) pairs
# --------------------------------------------------------------------------- #
def topk(frac: float = 0.01, k: Optional[int] = None) -> Compressor:
    def k_of(d: int) -> int:
        return max(1, min(d, k if k is not None else int(round(frac * d))))

    def encode(g: jax.Array, key) -> Tuple[jax.Array, jax.Array, int]:
        kk = k_of(g.size)
        _, idx = jax.lax.top_k(jnp.abs(g), kk)
        return idx.astype(jnp.int32), g[idx], g.size

    def decode(code) -> jax.Array:
        idx, vals, d = code
        return jnp.zeros((d,), jnp.float32).at[idx].set(vals)

    return Compressor("topk", encode, decode,
                      nbytes=lambda d: 8 * k_of(d))      # int32 idx + fp32 val


_REGISTRY = {"qsgd": qsgd, "signsgd": signsgd, "topk": topk}


def get_compressor(name: Optional[str], **kw) -> Optional[Compressor]:
    """'qsgd' | 'signsgd' | 'topk' | 'none'/None -> Compressor or None."""
    if name is None or name in ("none", ""):
        return None
    if name not in _REGISTRY:
        raise ValueError(f"unknown compressor {name!r}; options: "
                         f"{sorted(_REGISTRY)} or 'none'")
    return _REGISTRY[name](**kw)


def compress_tree(comp: Compressor, tree: Any, key: jax.Array) -> Tuple[Any, int]:
    """decode(encode(leaf)) every leaf; returns (tree', total wire bytes).

    The byte total is a static (host-side) int — it feeds the ledger at
    trace time; the returned tree keeps each leaf's shape and dtype.
    """
    leaves, treedef = jax.tree.flatten(tree)
    out, nbytes = [], 0
    for i, g in enumerate(leaves):
        flat = g.reshape(-1).astype(jnp.float32)
        dec = comp.decode(comp.encode(flat, jax.random.fold_in(key, i)))
        out.append(dec.reshape(g.shape).astype(g.dtype))
        nbytes += comp.nbytes(flat.size)
    return jax.tree.unflatten(treedef, out), nbytes
