"""Instrumented collectives: thin ``jax.lax`` wrappers + a byte ledger.

The paper's headline claim is a *communication load*: (tau-1+d)/tau scalars
per worker per iteration for HO-SGD vs d for sync-SGD (Table 1).  The
``CommLedger`` turns that from an analytic formula into a measured quantity:
every collective routed through this module records its logical payload
bytes (per worker) at trace time, and the ledger accumulates those bytes per
host-level step call.

How it composes with jit: ``ledger.wrap(name, fn)`` returns a callable that
(a) marks the ledger active while ``fn`` runs — so the wrappers below, hit
during the jit *trace*, register the program's per-step byte records — and
(b) bumps the step counter on every call.  jit caches the trace, so records
register once per program and the counter does the per-step accounting;
a retrace (new shapes) simply re-registers the program's records.

Accounting semantics (documented contract — Table-1 tests rely on it):
  * ``all_gather``: bytes of the *gathered result* per worker — m scalars
    gathered over m workers is ``4*m`` bytes, independent of d.
  * ``psum``/``pmean`` and ``note_all_reduce``: bytes of the reduced payload
    per worker — a d-dim fp32 gradient all-reduce is ``4*d`` bytes.
  * ``payload=False`` marks diagnostics (e.g. averaging the monitoring loss)
    that are *not* part of the algorithm's communication; they appear in the
    per-kind breakdown but are excluded from ``bytes_per_step``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

Axes = Union[str, Sequence[str]]

_ACTIVE: List[Tuple["CommLedger", str]] = []


@dataclass
class _Record:
    kind: str
    tag: str
    nbytes: int
    payload: bool


@dataclass
class CommLedger:
    """Host-side per-program byte accounting for collectives."""

    programs: Dict[str, List[_Record]] = field(default_factory=dict)
    steps: Dict[str, int] = field(default_factory=dict)
    _recording: Optional[List[_Record]] = None

    # --- registration (trace time) ------------------------------------------
    def record(self, kind: str, nbytes: int, *, tag: str = "",
               payload: bool = True) -> None:
        if self._recording is not None:
            self._recording.append(_Record(kind, tag, int(nbytes), payload))

    # --- program wrapping ----------------------------------------------------
    def wrap(self, name: str, fn):
        """Instrument a step callable. Wrap BEFORE the first (tracing) call."""
        def wrapped(*args, **kwargs):
            self._recording, saved = [], self._recording
            _ACTIVE.append((self, name))
            try:
                out = fn(*args, **kwargs)
            finally:
                _ACTIVE.pop()
                recorded, self._recording = self._recording, saved
            if recorded:                      # fresh trace: (re)register program
                self.programs[name] = recorded
            self.steps[name] = self.steps.get(name, 0) + 1
            return out
        return wrapped

    # --- queries --------------------------------------------------------------
    def bytes_per_step(self, name: str, payload_only: bool = True) -> int:
        return sum(r.nbytes for r in self.programs.get(name, [])
                   if r.payload or not payload_only)

    def total_bytes(self, payload_only: bool = True) -> int:
        return sum(self.bytes_per_step(n, payload_only) * s
                   for n, s in self.steps.items())

    def by_kind(self, name: str) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for r in self.programs.get(name, []):
            key = f"{r.kind}:{r.tag}" if r.tag else r.kind
            out[key] = out.get(key, 0) + r.nbytes
        return out

    def summary(self) -> Dict[str, Any]:
        return {
            name: {
                "steps": self.steps.get(name, 0),
                "bytes_per_step": self.bytes_per_step(name),
                "bytes_total": self.bytes_per_step(name) * self.steps.get(name, 0),
                "by_kind": self.by_kind(name),
            }
            for name in sorted(set(self.programs) | set(self.steps))
        }

    def reset(self) -> None:
        self.steps.clear()


def _record_active(kind: str, nbytes: int, tag: str, payload: bool) -> None:
    if _ACTIVE:
        _ACTIVE[-1][0].record(kind, nbytes, tag=tag, payload=payload)


def _tree_nbytes(tree: Any) -> int:
    return sum(int(x.size) * jnp.dtype(x.dtype).itemsize
               for x in jax.tree.leaves(tree))


# --------------------------------------------------------------------------- #
# traced wrappers (call inside jit / shard_map bodies)
# --------------------------------------------------------------------------- #
def all_gather(x: jax.Array, axes: Axes, *, tiled: bool = False,
               tag: str = "", payload: bool = True) -> jax.Array:
    """``jax.lax.all_gather`` that books the gathered result's bytes.

    The ZO step's entire inter-worker traffic goes through here: one fp32
    scalar per worker gathered over m workers books exactly ``4*m`` bytes.
    """
    out = jax.lax.all_gather(x, axis_name=tuple(axes) if not isinstance(axes, str) else axes,
                             tiled=tiled)
    _record_active("all_gather", int(out.size) * out.dtype.itemsize, tag, payload)
    return out


def psum(x: Any, axes: Axes, *, tag: str = "", payload: bool = True) -> Any:
    out = jax.lax.psum(x, tuple(axes) if not isinstance(axes, str) else axes)
    _record_active("psum", _tree_nbytes(out), tag, payload)
    return out


def pmean(x: Any, axes: Axes, *, tag: str = "", payload: bool = True) -> Any:
    out = jax.lax.pmean(x, tuple(axes) if not isinstance(axes, str) else axes)
    _record_active("pmean", _tree_nbytes(out), tag, payload)
    return out


def note(kind: str, tree: Any, *, nbytes: Optional[int] = None,
         tag: str = "", payload: bool = True) -> Any:
    """Book a collective without emitting one (identity in the program).

    For exchanges the compiled program realizes some other way — GSPMD-
    inserted reductions, or the auto-mode ZO fallback on old jax where the
    coefficient gather is materialized by the partitioner rather than an
    explicit ``all_gather`` op.  ``tree``'s bytes are booked unless
    ``nbytes`` overrides (compressed wire formats).
    """
    _record_active(kind, _tree_nbytes(tree) if nbytes is None else int(nbytes),
                   tag, payload)
    return tree


def note_all_reduce(tree: Any, *, nbytes: Optional[int] = None,
                    tag: str = "", payload: bool = True) -> Any:
    """Book an all-reduce that XLA inserts implicitly (GSPMD data parallelism).

    The FO step's d-dim gradient reduction is not an explicit ``psum`` — the
    partitioner materializes it from the sharded-batch/replicated-params
    math — so the step books it here at trace time.  Returns ``tree``
    unchanged (identity in the compiled program).  Pass ``nbytes`` to book a
    different wire size than the tree's (compressed all-reduce).
    """
    return note("all_reduce", tree, nbytes=nbytes, tag=tag, payload=payload)
