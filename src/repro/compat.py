"""jax version compatibility: one place that knows both API generations.

The codebase targets the current jax API (``jax.set_mesh``,
``jax.shard_map(..., axis_names=..., check_vma=...)``); this container ships
jax 0.4.37, where those live under different names/signatures.  No new
dependencies — just dispatch on what the installed jax exposes.
"""
from __future__ import annotations

import contextlib
from typing import Any, Optional, Set

import jax


# jax 0.4.x's experimental shard_map accepts partial-auto (``auto=``), but
# the 0.4.x SPMD partitioner aborts on any collective inside the manual
# region (PartitionId UNIMPLEMENTED / IsManualSubgroup CHECK at
# spmd_partitioner.cc:512, reproduced on CPU 0.4.37).  Callers that need
# collectives under partial-auto must branch on this and fall back to an
# auto-sharded (GSPMD) formulation.
HAS_PARTIAL_AUTO_COLLECTIVES = hasattr(jax, "shard_map")


def set_mesh(mesh):
    """``with set_mesh(mesh):`` — jax.set_mesh when present, else the legacy
    global-mesh context (``with mesh:``), which is what 0.4.x pjit reads."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return mesh  # Mesh is itself a context manager on 0.4.x


def shard_map(f, mesh, in_specs, out_specs,
              axis_names: Optional[Set[str]] = None, check_vma: bool = True):
    """New-API partial-auto shard_map (``axis_names`` = the manual axes).

    Deliberately NOT bridged to 0.4.x's jax.experimental.shard_map: its
    ``auto=`` form exists but the partitioner aborts on any collective in
    the manual region (see HAS_PARTIAL_AUTO_COLLECTIVES above), so a
    translation layer would only move the crash from import time to compile
    time.  Callers must gate on HAS_PARTIAL_AUTO_COLLECTIVES and use an
    auto-sharded formulation on old jax (core.distributed.make_zo_step does).
    """
    assert HAS_PARTIAL_AUTO_COLLECTIVES, \
        "partial-auto shard_map is unusable on jax 0.4.x; gate on " \
        "compat.HAS_PARTIAL_AUTO_COLLECTIVES"
    kw = {}
    if axis_names is not None:
        kw["axis_names"] = set(axis_names)
    return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_vma=check_vma, **kw)
