"""Sharding-aware pytree checkpointing (npz payload + msgpack manifest).

No orbax in this environment; this implements the minimum a production
trainer needs: atomic step directories, a manifest with tree structure and
dtypes, restore onto arbitrary shardings, and latest-step discovery.
"""
from __future__ import annotations

import os
import shutil
import tempfile
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten_with_names(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        names.append(jax.tree_util.keystr(path))
        leaves.append(leaf)
    return names, leaves, treedef


def save(ckpt_dir: str, step: int, tree: Any) -> str:
    """Atomically save ``tree`` under ``ckpt_dir/step_<step>``."""
    names, leaves, _ = _flatten_with_names(tree)
    # method state may carry python scalars (e.g. the adaptive-tau since_fo
    # counter); canonicalize via numpy, which keeps int64/float64 width —
    # jnp.asarray under the default x64-disabled mode would round floats to
    # fp32 and overflow on ints >= 2**31
    leaves = [x if hasattr(x, "dtype") else np.asarray(x) for x in leaves]
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        # dtypes numpy can't store (bfloat16) ride as fp32 payloads; the
        # manifest records the logical dtype for exact restore (bf16->f32
        # widening is lossless)
        dtypes = [str(x.dtype) for x in leaves]
        arrays = {}
        for i, x in enumerate(leaves):
            h = jax.device_get(x)
            a = np.asarray(h) if dtypes[i] != "bfloat16" else np.asarray(
                jax.device_get(jnp.asarray(x).astype(jnp.float32)))
            arrays[f"a{i}"] = a
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        manifest = {
            "step": step,
            "names": names,
            "dtypes": dtypes,
            "shapes": [list(x.shape) for x in leaves],
        }
        with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
            f.write(msgpack.packb(manifest))
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except Exception:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.startswith(".")
    ]
    return max(steps) if steps else None


def restore(ckpt_dir: str, like: Any, step: Optional[int] = None,
            shardings: Any = None) -> Any:
    """Restore into the structure of ``like``; optionally place on shardings."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    data = np.load(os.path.join(path, "arrays.npz"))
    names, leaves_like, treedef = _flatten_with_names(like)
    if names != manifest["names"]:
        raise ValueError(
            f"checkpoint tree mismatch:\n saved={manifest['names'][:5]}...\n"
            f" expected={names[:5]}..."
        )
    # 64-bit payloads (canonicalized python scalars) stay numpy: jax's
    # default x64-disabled mode would silently truncate them to 32 bits
    leaves = [
        data[f"a{i}"] if jnp.dtype(dt).itemsize == 8
        else jnp.asarray(data[f"a{i}"]).astype(dt)
        for i, dt in enumerate(manifest["dtypes"])
    ]
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree, manifest["step"]
