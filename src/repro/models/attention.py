"""GQA attention: RoPE, qk-norm, logit soft-capping, sliding window, KV cache."""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope, dense_init, rmsnorm, softcap

Params = Dict[str, jax.Array]


def init_attention(key, cfg: ModelConfig, dtype) -> Params:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h * hd), dtype),
        "wk": dense_init(ks[1], (d, kv * hd), dtype),
        "wv": dense_init(ks[2], (d, kv * hd), dtype),
        "wo": dense_init(ks[3], (h * hd, d), dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), jnp.float32)
        p["k_norm"] = jnp.zeros((hd,), jnp.float32)
    return p


def _project_qkv(cfg: ModelConfig, p: Params, x: jax.Array, positions: jax.Array):
    B, S, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, h, hd)
    k = (x @ p["wk"]).reshape(B, S, kv, hd)
    v = (x @ p["wv"]).reshape(B, S, kv, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if cfg.encoder_only:
        return q, k, v  # hubert/w2v2 use absolute (stub) features, no rope
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _attend(
    cfg: ModelConfig,
    q: jax.Array,                 # (B, Sq, H, hd)
    k: jax.Array,                 # (B, Sk, KV, hd)
    v: jax.Array,                 # (B, Sk, KV, hd)
    q_positions: jax.Array,       # (B, Sq) or (Sq,)
    k_positions: jax.Array,       # (B, Sk) or (Sk,)
    window: Optional[jax.Array],  # scalar int32 or None (None = full attention)
    causal: bool,
) -> jax.Array:
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    rep = H // KV
    qg = q.reshape(B, Sq, KV, rep, hd)
    logits = jnp.einsum(
        "bqgrd,bkgd->bgrqk", qg.astype(jnp.float32), k.astype(jnp.float32)
    ) / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    if cfg.attn_softcap:
        logits = softcap(logits, cfg.attn_softcap)
    qp = jnp.broadcast_to(jnp.atleast_2d(q_positions), (B, Sq))
    kp = jnp.broadcast_to(jnp.atleast_2d(k_positions), (B, k.shape[1]))
    rel = qp[:, :, None] - kp[:, None, :]               # (B, Sq, Sk)
    mask = jnp.ones_like(rel, dtype=bool)
    if causal:
        mask &= rel >= 0
    if window is not None:
        mask &= rel < window
    logits = jnp.where(mask[:, None, None, :, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, H * hd).astype(q.dtype)


def _attend_seq(cfg: ModelConfig, q, k, v, positions, window) -> jax.Array:
    """Full-sequence attention, q-chunked when configured.

    Dense masked attention holds (B, H, Sq, Sk) fp32 scores; streaming query
    blocks of ``cfg.attn_chunk`` bounds that to (B, H, chunk, Sk) — the
    XLA-level analogue of the Pallas flash kernel's VMEM tiling (which is the
    real-TPU path; see kernels/flash_attention.py).
    """
    B, S = q.shape[0], q.shape[1]
    causal = not cfg.encoder_only
    if cfg.use_pallas:
        # kernel path: needs one static window across layers (or all-full)
        ws = set(cfg.layer_windows())
        if len(ws) == 1:
            out = _flash_kernel_call(cfg, q, k, v, causal, next(iter(ws)))
            if out is not None:
                return out
    chunk = cfg.attn_chunk
    if chunk:
        while S % chunk:
            chunk //= 2
    if not chunk or S <= chunk:
        return _attend(cfg, q, k, v, positions, positions, window, causal)
    nc = S // chunk

    def body(_, xs):
        q_i, pos_i = xs                      # (B, chunk, H, hd), (chunk,)
        o = _attend(cfg, q_i, k, v, pos_i, positions, window, causal)
        return None, o

    if cfg.remat:
        body = jax.checkpoint(body)
    q_c = q.reshape(B, nc, chunk, *q.shape[2:]).swapaxes(0, 1)
    pos_c = positions.reshape(nc, chunk)
    _, outs = jax.lax.scan(
        body, None, (q_c, pos_c), unroll=nc if cfg.scan_unroll else 1)
    return outs.swapaxes(0, 1).reshape(B, S, -1)


def _flash_kernel_call(cfg: ModelConfig, q, k, v, causal, w_static):
    """Dispatch to the Pallas flash-attention kernel when shapes allow."""
    S = q.shape[1]
    if S % 128 and S % 64:
        return None  # fall back to the jnp path for unaligned smoke shapes
    from repro.kernels import ops
    block = 128 if S % 128 == 0 else 64
    out = ops.flash_attention(
        q, k, v, causal=causal, window=w_static, softcap=cfg.attn_softcap,
        block_q=block, block_k=block)
    B = q.shape[0]
    return out.reshape(B, S, -1)


def attention_forward(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,               # (B, S, D)
    window: Optional[jax.Array] = None,
) -> jax.Array:
    """Full-sequence attention (train / prefill), causal unless encoder_only."""
    B, S, _ = x.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    q, k, v = _project_qkv(cfg, p, x, positions)
    out = _attend_seq(cfg, q, k, v, positions, window)
    return out @ p["wo"]


def attention_prefill(
    cfg: ModelConfig, p: Params, x: jax.Array, window: Optional[jax.Array] = None
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Like forward but also returns the (k, v) cache."""
    B, S, _ = x.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    q, k, v = _project_qkv(cfg, p, x, positions)
    out = _attend_seq(cfg, q, k, v, positions, window)
    return out @ p["wo"], (k, v)


def _hd_model_spec(ndim: int):
    """P(..., 'model') on the trailing head_dim, when a mesh is ambient.

    Decode attention with an hd-sharded cache needs q/k/v contraction dims
    aligned, or the partitioner all-gathers the WHOLE cache over the model
    axis per layer (measured: 1 GiB fp32/layer for gemma2 decode_32k —
    EXPERIMENTS.md §Perf iteration 3)."""
    from jax.sharding import PartitionSpec as P
    try:
        am = jax.sharding.get_abstract_mesh()
    except Exception:
        return None
    if am is None or not am.axis_names or "model" not in am.axis_names:
        return None
    return P(*([None] * (ndim - 1) + ["model"]))


def _constrain_hd(x: jax.Array) -> jax.Array:
    spec = _hd_model_spec(x.ndim)
    if spec is None:
        return x
    ms = jax.sharding.get_abstract_mesh().shape["model"]
    if x.shape[-1] % ms:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def attention_decode(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,                       # (B, 1, D) current token's hidden
    cache: Tuple[jax.Array, jax.Array],  # k,v (B, S, KV, hd); positions 0..S-1
    pos: jax.Array,                      # scalar int32: index of current token
    window: Optional[jax.Array] = None,
    static_window: Optional[int] = None,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """One-token decode against a KV cache; writes the new k/v at ``pos``.

    ``pos`` may be a scalar (the whole batch sits at one position — the seed
    synchronous path) or a ``(B,)`` vector (the serving slot pool, where every
    slot decodes at its own position; ``pos == -1`` marks an inactive slot:
    nothing is written and the causal mask blanks every read).

    When every layer shares one static window, ``static_window`` lets us read
    only the last ``W`` cache slots (a dynamic_slice) instead of streaming the
    whole cache — this is what makes windowed decode sub-linear in cache size.
    (Scalar-``pos`` only; the per-slot path masks the window via relative
    positions instead, since slots sit at different offsets.)
    """
    if jnp.ndim(pos) > 0:
        return _attention_decode_slots(cfg, p, x, cache, pos, window)
    k_cache, v_cache = cache
    S = k_cache.shape[1]
    positions = jnp.full((1,), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(cfg, p, x, positions)
    q = _constrain_hd(q)
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new.astype(k_cache.dtype), pos, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new.astype(v_cache.dtype), pos, axis=1)
    if static_window is not None and static_window < S:
        W = static_window
        start = jnp.clip(pos - W + 1, 0, S - W)
        k_read = jax.lax.dynamic_slice_in_dim(k_cache, start, W, axis=1)
        v_read = jax.lax.dynamic_slice_in_dim(v_cache, start, W, axis=1)
        k_positions = start + jnp.arange(W, dtype=jnp.int32)
    else:
        k_read, v_read = k_cache, v_cache
        k_positions = jnp.arange(S, dtype=jnp.int32)
    k_read = _constrain_hd(k_read)
    v_read = _constrain_hd(v_read)
    # beyond-pos slots are masked by the causal rel>=0 test (q position == pos)
    out = _attend(
        cfg, q, k_read, v_read, positions, k_positions, window, causal=True
    )
    return out @ p["wo"], (k_cache, v_cache)


def _attention_decode_slots(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,                        # (B, 1, D) current token per slot
    cache: Tuple[jax.Array, jax.Array],  # k,v (B, S, KV, hd)
    pos: jax.Array,                      # (B,) int32 per-slot position, -1 = inactive
    window: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Per-slot decode: each batch row writes/reads at its OWN position.

    The write is a masked select (one row of the length-S axis per slot)
    rather than a dynamic_update_slice, because start indices differ per
    row; inactive slots (``pos == -1``) match no row and write nothing.
    Reads stream the full cache — the causal test ``q_pos - k_pos >= 0``
    limits each slot to its own live prefix, and the sliding window (when
    configured) is enforced by the same relative-position mask."""
    k_cache, v_cache = cache
    S = k_cache.shape[1]
    positions = pos[:, None].astype(jnp.int32)           # (B, 1) q positions
    q, k_new, v_new = _project_qkv(cfg, p, x, positions)
    q = _constrain_hd(q)
    write = (jnp.arange(S, dtype=jnp.int32)[None, :] == positions)[..., None, None]
    k_cache = jnp.where(write, k_new.astype(k_cache.dtype), k_cache)
    v_cache = jnp.where(write, v_new.astype(v_cache.dtype), v_cache)
    k_positions = jnp.arange(S, dtype=jnp.int32)
    out = _attend(
        cfg, q, _constrain_hd(k_cache), _constrain_hd(v_cache),
        positions, k_positions, window, causal=True,
    )
    return out @ p["wo"], (k_cache, v_cache)
