"""Composable decoder/encoder transformer covering all assigned architectures.

One homogeneous ``lax.scan`` over stacked per-layer params drives every arch;
per-layer attention windows are a scanned int32 array (FULL = 2**30 means no
window).  This keeps HLO size O(1) in depth — the roofline reader corrects
the scan-body single-count (see benchmarks/roofline.py).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    apply_mlp,
    apply_norm,
    embed_init,
    init_mlp,
    init_norm,
    rmsnorm,
    softcap,
)

Params = Dict
FULL_WINDOW = 1 << 30
MOE_AUX_COEF = 0.01


def windows_array(cfg: ModelConfig) -> jax.Array:
    return jnp.asarray(
        [FULL_WINDOW if w is None else int(w) for w in cfg.layer_windows()],
        jnp.int32,
    )


def uniform_static_window(cfg: ModelConfig) -> Optional[int]:
    """The single static window if every layer shares one, else None."""
    ws = set(cfg.layer_windows())
    if len(ws) == 1 and None not in ws:
        return int(next(iter(ws)))
    return None


# --------------------------------------------------------------------------- #
# init
# --------------------------------------------------------------------------- #
def _init_layer(key, cfg: ModelConfig, dtype) -> Params:
    ks = jax.random.split(key, 6)
    p: Params = {
        "norm1": init_norm(cfg, cfg.d_model),
        "norm2": init_norm(cfg, cfg.d_model),
    }
    if cfg.post_norms:
        p["post_norm1"] = init_norm(cfg, cfg.d_model)
        p["post_norm2"] = init_norm(cfg, cfg.d_model)
    if cfg.has_attention:
        p["attn"] = attn.init_attention(ks[0], cfg, dtype)
    if cfg.has_ssm:
        p["mamba"] = ssm_mod.init_mamba(ks[1], cfg, dtype)
    if cfg.arch_type == "hybrid":
        p["attn_out_scale"] = jnp.zeros((cfg.d_model,), jnp.float32)
        p["mamba_out_scale"] = jnp.zeros((cfg.d_model,), jnp.float32)
    if cfg.is_moe:
        p["moe"] = moe_mod.init_moe(ks[2], cfg, dtype)
        if cfg.moe_dense_residual:
            p["dense_mlp"] = init_mlp(ks[3], cfg, cfg.dense_d_ff, dtype)
    elif cfg.d_ff:
        p["mlp"] = init_mlp(ks[2], cfg, cfg.d_ff, dtype)
    return p


def init_model(key, cfg: ModelConfig) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    k_embed, k_layers, k_head = jax.random.split(key, 3)
    params: Params = {}
    if cfg.frontend != "audio":
        params["embed"] = embed_init(k_embed, (cfg.vocab_size, cfg.d_model), dtype)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    params["layers"] = jax.vmap(lambda k: _init_layer(k, cfg, dtype))(layer_keys)
    params["final_norm"] = init_norm(cfg, cfg.d_model)
    if cfg.tie_embeddings and cfg.frontend != "audio":
        pass  # head = embed.T
    else:
        params["head"] = embed_init(k_head, (cfg.d_model, cfg.vocab_size), dtype)
    return params


# --------------------------------------------------------------------------- #
# blocks
# --------------------------------------------------------------------------- #
def _mix(cfg: ModelConfig, lp: Params, xn: jax.Array, window: jax.Array) -> jax.Array:
    """Sequence-mixing sublayer (attention / mamba / hymba parallel fusion)."""
    if cfg.arch_type == "ssm":
        return ssm_mod.mamba_forward(cfg, lp["mamba"], xn)
    if cfg.arch_type == "hybrid":
        a = attn.attention_forward(cfg, lp["attn"], xn, window)
        m = ssm_mod.mamba_forward(cfg, lp["mamba"], xn)
        return 0.5 * (
            rmsnorm(a, lp["attn_out_scale"], cfg.norm_eps)
            + rmsnorm(m, lp["mamba_out_scale"], cfg.norm_eps)
        )
    return attn.attention_forward(cfg, lp["attn"], xn, window)


def _ffn(cfg: ModelConfig, lp: Params, xn: jax.Array) -> Tuple[jax.Array, jax.Array]:
    if cfg.is_moe:
        y, aux = moe_mod.moe_forward(cfg, lp["moe"], xn)
        if cfg.moe_dense_residual:
            y = y + apply_mlp(cfg, lp["dense_mlp"], xn)
        return y, aux
    if cfg.d_ff:
        return apply_mlp(cfg, lp["mlp"], xn), jnp.zeros((), jnp.float32)
    return jnp.zeros_like(xn), jnp.zeros((), jnp.float32)


def _block(cfg: ModelConfig, lp: Params, x: jax.Array, window: jax.Array):
    mix = _mix(cfg, lp, apply_norm(cfg, lp["norm1"], x), window)
    if cfg.post_norms:
        mix = apply_norm(cfg, lp["post_norm1"], mix)
    x = x + mix
    ff, aux = _ffn(cfg, lp, apply_norm(cfg, lp["norm2"], x))
    if cfg.post_norms:
        ff = apply_norm(cfg, lp["post_norm2"], ff)
    return x + ff, aux


# --------------------------------------------------------------------------- #
# embedding / inputs
# --------------------------------------------------------------------------- #
def embed_batch(cfg: ModelConfig, params: Params, batch: Dict) -> jax.Array:
    if cfg.frontend == "audio":
        return batch["features"]
    scale = math.sqrt(cfg.d_model)
    if cfg.frontend == "vision":
        text = jnp.take(params["embed"], batch["tokens"], axis=0) * scale
        return jnp.concatenate(
            [batch["image_embeds"].astype(text.dtype), text], axis=1
        )
    return jnp.take(params["embed"], batch["tokens"], axis=0) * scale


def compute_logits(cfg: ModelConfig, params: Params, h: jax.Array) -> jax.Array:
    h = apply_norm(cfg, params["final_norm"], h)
    head = params["embed"].T if "head" not in params else params["head"]
    logits = h @ head
    if cfg.final_softcap:
        logits = softcap(logits, cfg.final_softcap)
    return logits


# --------------------------------------------------------------------------- #
# forward (train / prefill)
# --------------------------------------------------------------------------- #
def _unroll(cfg: ModelConfig):
    # the dry-run's depth-point lowerings unroll so cost_analysis sees every
    # layer (a lax.scan body is counted once regardless of trip count)
    return cfg.n_layers if cfg.scan_unroll else 1


def forward_hidden(cfg: ModelConfig, params: Params, h: jax.Array):
    windows = windows_array(cfg)

    def body(carry, xs):
        x, aux = carry
        lp, win = xs
        x, a = _block(cfg, lp, x, win)
        return (x, aux + a), None

    if cfg.remat:
        body = jax.checkpoint(body)
    (h, aux), _ = jax.lax.scan(
        body, (h, jnp.zeros((), jnp.float32)), (params["layers"], windows),
        unroll=_unroll(cfg),
    )
    return h, aux


def forward_logits(cfg: ModelConfig, params: Params, batch: Dict):
    h = embed_batch(cfg, params, batch)
    h, aux = forward_hidden(cfg, params, h)
    return compute_logits(cfg, params, h), aux


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean CE over positions with label >= 0. logits (B,S,V), labels (B,S)."""
    mask = labels >= 0
    safe = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), safe[..., None], axis=-1
    )[..., 0]
    ce = (lse - gold) * mask
    return jnp.sum(ce) / jnp.maximum(jnp.sum(mask), 1)


def ce_chunk_size(cfg: ModelConfig) -> int:
    """Vocab-chunk size for the streaming CE (0 = dense logits).

    Production default: chunk vocabularies >= 16384 so the live logits buffer
    is B*S*chunk instead of B*S*V — large-vocab archs cannot fit dense fp32
    logits + their gradients in HBM at the assigned batch sizes.
    """
    if cfg.ce_chunk > 0:
        return cfg.ce_chunk if cfg.vocab_size > cfg.ce_chunk else 0
    if cfg.ce_chunk < 0 or cfg.vocab_size < 16384:
        return 0
    return 8192


def cross_entropy_streaming(cfg: ModelConfig, head: jax.Array, h: jax.Array,
                            labels: jax.Array) -> jax.Array:
    """CE with vocab-chunked logits: scan over (D, chunk) head slices with a
    running (max, sumexp, gold) carry; logits are rematerialized in the
    backward pass instead of stored.  The head is zero-padded to a multiple
    of the chunk; padded columns are masked out of the running stats."""
    chunk = ce_chunk_size(cfg)
    B, S, D = h.shape
    V = head.shape[1]
    if not chunk or V <= chunk:
        return cross_entropy(jnp.einsum("bsd,dv->bsv", h, head), labels)
    T = B * S
    hf = h.reshape(T, D)
    lab = labels.reshape(T)
    mask = lab >= 0
    safe = jnp.maximum(lab, 0)
    n_chunks = (V + chunk - 1) // chunk

    # dynamic_slice of the head per chunk (no padded / transposed copy of the
    # (D, V) matrix — for a 152k-vocab model that copy is 1.5 GiB per eval).
    # The final chunk's slice start clamps to V-chunk, so it may overlap the
    # previous chunk; already-counted columns are masked out.
    def body(carry, c_idx):
        m, s, gold = carry
        start = jnp.maximum(jnp.minimum(c_idx * chunk, V - chunk), 0)
        W_c = jax.lax.dynamic_slice(head, (0, start), (D, chunk))
        logits = (hf @ W_c).astype(jnp.float32)          # (T, chunk)
        if cfg.final_softcap:
            logits = softcap(logits, cfg.final_softcap)
        col = start + jnp.arange(chunk, dtype=jnp.int32)
        fresh = col >= c_idx * chunk                     # mask overlap columns
        logits = jnp.where(fresh[None, :], logits, -1e30)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        s = s * jnp.exp(m - m_new) + jnp.sum(jnp.exp(logits - m_new[:, None]), -1)
        rel = safe - start
        in_r = (rel >= 0) & (rel < chunk) & (safe >= c_idx * chunk)
        got = jnp.take_along_axis(logits, jnp.clip(rel, 0, chunk - 1)[:, None], 1)[:, 0]
        gold = gold + jnp.where(in_r, got, 0.0)
        return (m_new, s, gold), None

    body = jax.checkpoint(body)
    init = (jnp.full((T,), -1e30, jnp.float32), jnp.zeros((T,), jnp.float32),
            jnp.zeros((T,), jnp.float32))
    (m, s, gold), _ = jax.lax.scan(
        body, init, jnp.arange(n_chunks, dtype=jnp.int32),
        unroll=_unroll(cfg),
    )
    ce = (m + jnp.log(s) - gold) * mask
    return jnp.sum(ce) / jnp.maximum(jnp.sum(mask), 1)


def loss_fn(cfg: ModelConfig, params: Params, batch: Dict) -> jax.Array:
    h = embed_batch(cfg, params, batch)
    h, aux = forward_hidden(cfg, params, h)
    if ce_chunk_size(cfg):
        h = apply_norm(cfg, params["final_norm"], h)
        head = params["embed"].T if "head" not in params else params["head"]
        ce = cross_entropy_streaming(cfg, head, h, batch["labels"])
    else:
        logits = compute_logits(cfg, params, h)
        ce = cross_entropy(logits, batch["labels"])
    return ce + MOE_AUX_COEF * aux


# --------------------------------------------------------------------------- #
# serving: prefill + single-token decode with stacked per-layer caches
# --------------------------------------------------------------------------- #
def init_caches(cfg: ModelConfig, batch: int, seq_len: int, dtype) -> Dict:
    caches: Dict = {}
    L = cfg.n_layers
    if cfg.has_attention:
        shape = (L, batch, seq_len, cfg.n_kv_heads, cfg.head_dim)
        caches["k"] = jnp.zeros(shape, dtype)
        caches["v"] = jnp.zeros(shape, dtype)
    if cfg.has_ssm:
        caches["conv"] = jnp.zeros((L, batch, cfg.ssm_conv - 1, cfg.d_inner), dtype)
        caches["ssm"] = jnp.zeros((L, batch, cfg.d_inner, cfg.ssm_state), jnp.float32)
    return caches


def _block_decode(cfg: ModelConfig, lp: Params, x, pos, cache_l: Dict, window):
    new_cache: Dict = {}
    xn = apply_norm(cfg, lp["norm1"], x)
    static_w = uniform_static_window(cfg)
    if cfg.arch_type == "ssm":
        mix, (new_cache["conv"], new_cache["ssm"]) = ssm_mod.mamba_decode(
            cfg, lp["mamba"], xn, (cache_l["conv"], cache_l["ssm"])
        )
    elif cfg.arch_type == "hybrid":
        a, (new_cache["k"], new_cache["v"]) = attn.attention_decode(
            cfg, lp["attn"], xn, (cache_l["k"], cache_l["v"]), pos, window,
            static_window=static_w,
        )
        m, (new_cache["conv"], new_cache["ssm"]) = ssm_mod.mamba_decode(
            cfg, lp["mamba"], xn, (cache_l["conv"], cache_l["ssm"])
        )
        mix = 0.5 * (
            rmsnorm(a, lp["attn_out_scale"], cfg.norm_eps)
            + rmsnorm(m, lp["mamba_out_scale"], cfg.norm_eps)
        )
    else:
        mix, (new_cache["k"], new_cache["v"]) = attn.attention_decode(
            cfg, lp["attn"], xn, (cache_l["k"], cache_l["v"]), pos, window,
            static_window=static_w,
        )
    if cfg.post_norms:
        mix = apply_norm(cfg, lp["post_norm1"], mix)
    x = x + mix
    ff, _ = _ffn(cfg, lp, apply_norm(cfg, lp["norm2"], x))
    if cfg.post_norms:
        ff = apply_norm(cfg, lp["post_norm2"], ff)
    return x + ff, new_cache


def decode_step(cfg: ModelConfig, params: Params, token: jax.Array, pos, caches: Dict):
    """One decode step. token (B,) int32, pos scalar int32; returns (logits(B,V), caches)."""
    scale = math.sqrt(cfg.d_model)
    h = jnp.take(params["embed"], token, axis=0)[:, None, :] * scale  # (B,1,D)
    windows = windows_array(cfg)

    def body(x, xs):
        lp, win, cache_l = xs
        x, new_cache = _block_decode(cfg, lp, x, pos, cache_l, win)
        return x, new_cache

    h, new_caches = jax.lax.scan(
        body, h, (params["layers"], windows, caches), unroll=_unroll(cfg))
    logits = compute_logits(cfg, params, h)[:, 0]
    return logits, new_caches


def decode_step_slots(cfg: ModelConfig, params: Params, tokens: jax.Array,
                      pos: jax.Array, caches: Dict):
    """One decode step over a slot pool: every row at its OWN position.

    tokens (B,) int32 (row b's current token), pos (B,) int32 (row b's
    position; -1 = inactive slot — nothing written, logits are don't-care);
    returns (logits (B, V), caches).  This is the continuous-batching decode
    program: the batch axis is the KV-cache slot pool, and admission/eviction
    only change ``tokens``/``pos``, never the jitted program's shapes.
    """
    scale = math.sqrt(cfg.d_model)
    h = jnp.take(params["embed"], tokens, axis=0)[:, None, :] * scale  # (B,1,D)
    windows = windows_array(cfg)
    pos = jnp.asarray(pos, jnp.int32)

    def body(x, xs):
        lp, win, cache_l = xs
        x, new_cache = _block_decode(cfg, lp, x, pos, cache_l, win)
        return x, new_cache

    h, new_caches = jax.lax.scan(
        body, h, (params["layers"], windows, caches), unroll=_unroll(cfg))
    logits = compute_logits(cfg, params, h)[:, 0]
    return logits, new_caches


def prefill(cfg: ModelConfig, params: Params, batch: Dict):
    """Process the prompt, returning last-position logits and filled caches."""
    h, caches = _prefill_hidden(cfg, params, batch)
    logits = compute_logits(cfg, params, h[:, -1:, :])[:, 0]
    return logits, caches


def prefill_at(cfg: ModelConfig, params: Params, batch: Dict, last_idx: jax.Array):
    """Prefill over a (possibly right-padded) prompt rectangle, returning the
    logits at per-row position ``last_idx`` (B,) int32 — the last REAL prompt
    token — and the filled caches.

    This is the bucketed-prefill target: prompts are right-padded to a fixed
    bucket length so one jitted executable serves every prompt in the bucket,
    and causal attention guarantees positions <= last_idx never see the pad
    tail.  (Attention-only configs; an SSM's post-prompt state integrates the
    whole sequence, so SSM/hybrid prefills must run at exact length where
    ``last_idx`` is simply the final position.)
    """
    h, caches = _prefill_hidden(cfg, params, batch)
    h_last = jnp.take_along_axis(
        h, last_idx.astype(jnp.int32)[:, None, None], axis=1)  # (B, 1, D)
    logits = compute_logits(cfg, params, h_last)[:, 0]
    return logits, caches


def _prefill_hidden(cfg: ModelConfig, params: Params, batch: Dict):
    """Shared prefill scan: full-sequence hidden states + per-layer caches."""
    h = embed_batch(cfg, params, batch)
    windows = windows_array(cfg)

    # Mirrors _block but captures per-layer caches as scan outputs.
    def body_cache(carry, xs):
        x = carry
        lp, win = xs
        cache: Dict = {}
        xn = apply_norm(cfg, lp["norm1"], x)
        if cfg.arch_type == "ssm":
            mix = ssm_mod.mamba_forward(cfg, lp["mamba"], xn)
            cache["conv"], cache["ssm"] = _mamba_tail_state(cfg, lp["mamba"], xn)
        elif cfg.arch_type == "hybrid":
            a, (cache["k"], cache["v"]) = attn.attention_prefill(cfg, lp["attn"], xn, win)
            m = ssm_mod.mamba_forward(cfg, lp["mamba"], xn)
            cache["conv"], cache["ssm"] = _mamba_tail_state(cfg, lp["mamba"], xn)
            mix = 0.5 * (
                rmsnorm(a, lp["attn_out_scale"], cfg.norm_eps)
                + rmsnorm(m, lp["mamba_out_scale"], cfg.norm_eps)
            )
        else:
            mix, (cache["k"], cache["v"]) = attn.attention_prefill(cfg, lp["attn"], xn, win)
        if cfg.post_norms:
            mix = apply_norm(cfg, lp["post_norm1"], mix)
        x = x + mix
        ff, _ = _ffn(cfg, lp, apply_norm(cfg, lp["norm2"], x))
        if cfg.post_norms:
            ff = apply_norm(cfg, lp["post_norm2"], ff)
        return x + ff, cache

    h, caches = jax.lax.scan(
        body_cache, h, (params["layers"], windows), unroll=_unroll(cfg))
    return h, caches


def _mamba_tail_state(cfg: ModelConfig, mp: Params, xn: jax.Array):
    """Recompute the post-prompt (conv, ssm) state for decode continuation."""
    u, _ = jnp.split(xn @ mp["in_proj"], 2, axis=-1)
    K = cfg.ssm_conv
    conv_state = u[:, -(K - 1) :, :]
    u_c = jax.nn.silu(ssm_mod._causal_conv(mp, u, K))
    deltaA, deltaBu, _ = ssm_mod._ssm_inputs(cfg, mp, u_c)
    h = ssm_mod._assoc_scan(deltaA, deltaBu)[:, -1]
    return conv_state, h
