from repro.models.transformer import (  # noqa: F401
    decode_step,
    forward_logits,
    init_caches,
    init_model,
    loss_fn,
    prefill,
)
