"""The paper's §5.2 experiment model: a 2-layer fully-connected classifier.

The paper uses 1.3K + 1.3K hidden neurons (>1.69M params on 48–54 feature
datasets).  HO-SGD treats the model as a black box; this module provides the
same interface (init / loss_fn) as the transformer so every optimizer in
``repro.core`` runs against either.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def init_mlp_classifier(key, n_features: int, n_classes: int,
                        hidden: int = 1300, dtype=jnp.float32) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": dense_init(k1, (n_features, hidden), dtype),
        "b1": jnp.zeros((hidden,), dtype),
        "w2": dense_init(k2, (hidden, hidden), dtype),
        "b2": jnp.zeros((hidden,), dtype),
        "w3": dense_init(k3, (hidden, n_classes), dtype),
        "b3": jnp.zeros((n_classes,), dtype),
    }


def mlp_logits(params: Dict, x: jax.Array) -> jax.Array:
    h = jnp.tanh(x @ params["w1"] + params["b1"])
    h = jnp.tanh(h @ params["w2"] + params["b2"])
    return h @ params["w3"] + params["b3"]


def mlp_loss(params: Dict, batch: Dict) -> jax.Array:
    logits = mlp_logits(params, batch["x"])
    labels = batch["y"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(lse - gold)


def mlp_accuracy(params: Dict, batch: Dict) -> jax.Array:
    return jnp.mean(jnp.argmax(mlp_logits(params, batch["x"]), -1) == batch["y"])
