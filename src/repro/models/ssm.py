"""Mamba-1 selective SSM block (falcon-mamba / hymba mamba heads).

TPU adaptation (see DESIGN.md §4): the CUDA reference fuses the recurrence in
a warp-level kernel; in pure JAX we use ``jax.lax.associative_scan`` (log-depth,
VPU-friendly) which materialises the (B,S,d_inner,n) state in HBM.  The Pallas
``selective_scan`` kernel (kernels/selective_scan.py) removes that traffic by
keeping the running state in VMEM; ``cfg.ssm_chunk`` bounds peak memory for
the jnp path by scanning over sequence chunks.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init

Params = Dict[str, jax.Array]


def init_mamba(key, cfg: ModelConfig, dtype) -> Params:
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    dtr, K = cfg.dt_rank_actual, cfg.ssm_conv
    ks = jax.random.split(key, 5)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di), dtype),
        "conv_w": dense_init(ks[1], (K, di), dtype, scale=1.0),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "x_proj": dense_init(ks[2], (di, dtr + 2 * n), dtype),
        "dt_w": dense_init(ks[3], (dtr, di), dtype),
        # softplus(dt_b) ~= 0.01 at init (standard mamba dt bias init)
        "dt_b": jnp.full((di,), -4.6, jnp.float32),
        "A_log": jnp.log(jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32), (di, n))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], (di, d), dtype),
    }


def _causal_conv(p: Params, u: jax.Array, K: int) -> jax.Array:
    """Depthwise causal conv, kernel K: u (B,S,di) -> (B,S,di)."""
    B, S, di = u.shape
    padded = jnp.pad(u, ((0, 0), (K - 1, 0), (0, 0)))
    y = jnp.zeros_like(u, dtype=jnp.float32)
    for k in range(K):  # K is 4: unrolled shifts beat a conv op for clarity
        y = y + p["conv_w"][k].astype(jnp.float32) * padded[:, k : k + S].astype(jnp.float32)
    return (y + p["conv_b"]).astype(u.dtype)


def _ssm_inputs(cfg: ModelConfig, p: Params, u: jax.Array):
    """u (B,S,di) -> (deltaA, deltaBu, C) with shapes (B,S,di,n)/(B,S,n)."""
    dtr, n = cfg.dt_rank_actual, cfg.ssm_state
    x_dbl = (u @ p["x_proj"]).astype(jnp.float32)
    dt_low, Bmat, Cmat = jnp.split(x_dbl, [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus(dt_low @ p["dt_w"].astype(jnp.float32) + p["dt_b"])
    A = -jnp.exp(p["A_log"])                                  # (di, n)
    deltaA = jnp.exp(dt[..., None] * A)                       # (B,S,di,n)
    deltaBu = (dt * u.astype(jnp.float32))[..., None] * Bmat[..., None, :]
    return deltaA, deltaBu, Cmat


def _assoc_scan(deltaA: jax.Array, deltaBu: jax.Array, h0=None):
    """h[t] = deltaA[t]*h[t-1] + deltaBu[t] along axis=1 (seq)."""
    if h0 is not None:
        deltaBu = deltaBu.at[:, 0].add(deltaA[:, 0] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a2 * a1, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (deltaA, deltaBu), axis=1)
    return h


def mamba_mix(cfg: ModelConfig, p: Params, u: jax.Array) -> jax.Array:
    """Sequence mixing only (conv + selective scan), u (B,S,di) -> (B,S,di)."""
    u = jax.nn.silu(_causal_conv(p, u, cfg.ssm_conv))
    if cfg.use_pallas and u.shape[1] % 64 == 0 and cfg.d_inner % 64 == 0:
        # Pallas fused-scan path (TPU; interpret-validated): recompute the
        # kernel inputs without materializing (B,S,di,n)
        from repro.kernels import ops
        dtr, n = cfg.dt_rank_actual, cfg.ssm_state
        x_dbl = (u @ p["x_proj"]).astype(jnp.float32)
        dt_low, Bm, Cm = jnp.split(x_dbl, [dtr, dtr + n], axis=-1)
        dt = jax.nn.softplus(dt_low @ p["dt_w"].astype(jnp.float32) + p["dt_b"])
        A = -jnp.exp(p["A_log"])
        return ops.selective_scan(
            u.astype(jnp.float32), dt, Bm, Cm, A, p["D"],
            block_d=min(256, cfg.d_inner), block_s=min(128, u.shape[1]),
        ).astype(u.dtype)
    deltaA, deltaBu, Cmat = _ssm_inputs(cfg, p, u)
    if cfg.ssm_chunk and u.shape[1] > cfg.ssm_chunk:
        S, ck = u.shape[1], cfg.ssm_chunk
        assert S % ck == 0
        B, di, n = u.shape[0], cfg.d_inner, cfg.ssm_state

        def step(h, xs):
            dA, dBu = xs  # (B, ck, di, n) each
            h_seq = _assoc_scan(dA, dBu, h0=h)
            return h_seq[:, -1], h_seq

        rs = lambda t: t.reshape(B, S // ck, ck, di, n).swapaxes(0, 1)
        _, h = jax.lax.scan(step, jnp.zeros((B, di, n), jnp.float32), (rs(deltaA), rs(deltaBu)))
        h = h.swapaxes(0, 1).reshape(B, S, di, n)
    else:
        h = _assoc_scan(deltaA, deltaBu)
    y = jnp.einsum("bsdn,bsn->bsd", h, Cmat) + p["D"] * u.astype(jnp.float32)
    return y.astype(u.dtype)


def mamba_forward(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    """Full mamba block: x (B,S,D) -> (B,S,D)."""
    u, z = jnp.split(x @ p["in_proj"], 2, axis=-1)
    y = mamba_mix(cfg, p, u)
    return (y * jax.nn.silu(z)) @ p["out_proj"]


# --------------------------------------------------------------------------- #
# decode (single-token recurrence)
# --------------------------------------------------------------------------- #
def init_mamba_state(cfg: ModelConfig, batch: int, dtype) -> Tuple[jax.Array, jax.Array]:
    """(conv_state (B, K-1, di), ssm_state (B, di, n))."""
    return (
        jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), dtype),
        jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
    )


def mamba_decode(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,                                   # (B, 1, D)
    state: Tuple[jax.Array, jax.Array],
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    conv_state, h = state
    K = cfg.ssm_conv
    u, z = jnp.split(x[:, 0] @ p["in_proj"], 2, axis=-1)      # (B, di)
    window = jnp.concatenate([conv_state, u[:, None]], axis=1)  # (B, K, di)
    conv_y = jnp.einsum("bkd,kd->bd", window.astype(jnp.float32), p["conv_w"].astype(jnp.float32))
    u_c = jax.nn.silu(conv_y + p["conv_b"]).astype(u.dtype)
    deltaA, deltaBu, Cmat = _ssm_inputs(cfg, p, u_c[:, None])  # seq dim 1
    h = deltaA[:, 0] * h + deltaBu[:, 0]                       # (B, di, n)
    y = jnp.einsum("bdn,bn->bd", h, Cmat[:, 0]) + p["D"] * u_c.astype(jnp.float32)
    out = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["out_proj"]
    return out[:, None], (window[:, 1:], h)
