"""Token-choice top-k MoE with capacity-based gather dispatch.

TPU adaptation: GPU MoE stacks (megablocks) use ragged sparse kernels; the
TPU-idiomatic formulation (GShard/Switch lineage) routes through dense
gathers with a per-expert capacity so every matmul is MXU-shaped
``(E, C, d) x (E, d, f)``.  FLOPs scale with *active* tokens times the
capacity factor, so compiled cost analysis reflects the paper-style
6*N_active*D accounting.  Expert weights shard either on the FFN hidden dim
(``moe_sharding='tensor'``) or on the expert dim (``'expert'``) — the
collective pattern (all-reduce vs all-to-all-like regather) differs and is a
hillclimb lever (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init

Params = Dict[str, jax.Array]


def moe_capacity(cfg: ModelConfig, n_tokens: int) -> int:
    cap = int(math.ceil(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts))
    return max(8, ((cap + 7) // 8) * 8)  # pad to lane-friendly multiple


def init_moe(key, cfg: ModelConfig, dtype) -> Params:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    p = {
        "router": dense_init(ks[0], (d, e), jnp.float32),
        "wg": dense_init(ks[1], (e, d, f), dtype),
        "wu": dense_init(ks[2], (e, d, f), dtype),
        "wd": dense_init(ks[3], (e, f, d), dtype),
    }
    return p


def _expert_spec(cfg: ModelConfig, e_dim: int, hidden_dim=None):
    """PartitionSpec for (E, C, ...) dispatch tensors, matching the expert
    weights' sharding (expert dim over data under fsdp; hidden over model).
    Returns None when no mesh (or the axes) are available — smoke tests."""
    from jax.sharding import PartitionSpec as P
    try:
        am = jax.sharding.get_abstract_mesh()
    except Exception:
        return None
    if am is None or not am.axis_names or "model" not in am.axis_names:
        return None
    parts = [None, None, None]
    if cfg.fsdp and "data" in am.axis_names and cfg.n_experts % am.shape["data"] == 0:
        parts[e_dim] = "data"
    if hidden_dim is not None:
        parts[hidden_dim] = "model"
    return P(*parts)


def _constrain(x, spec):
    return x if spec is None else jax.lax.with_sharding_constraint(x, spec)


def moe_forward(
    cfg: ModelConfig, p: Params, x: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """x (B,S,D) -> (y (B,S,D), load-balance aux loss scalar).

    The (E, C, ...) dispatch tensors carry explicit sharding constraints
    matching the expert weights — without them the SPMD partitioner is free
    to replicate the expert matmuls per device (measured: ~100-380x FLOPs
    inflation on the MoE giants; EXPERIMENTS.md §Perf iteration 1).
    """
    B, S, D = x.shape
    T, k, E = B * S, cfg.top_k, cfg.n_experts
    C = moe_capacity(cfg, T)
    xf = x.reshape(T, D)

    logits = (xf.astype(jnp.float32)) @ p["router"]            # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert_ids = jax.lax.top_k(probs, k)                 # (T, k)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

    # load-balance auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=0)                               # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_ids, E, dtype=jnp.float32), axis=1), axis=0
    )
    aux = E * jnp.sum(me * ce)

    # position of each (token, slot) within its expert's capacity buffer,
    # via sort-based ranking.  NOT jnp.cumsum over the (T*k, E) one-hot: XLA
    # lowers cumsum to a ReduceWindow whose FLOP count is quadratic in T*k
    # (measured 1.1e17 flops/device for qwen3-moe's ZO step), and the
    # log-depth associative_scan alternative explodes compile time in the
    # unrolled cost-analysis lowerings (§Perf iteration 1).
    flat_e = expert_ids.reshape(T * k)                         # routing order: t-major
    order = jnp.argsort(flat_e, stable=True)                   # groups by expert,
    e_sorted = flat_e[order]                                   # token-order within
    starts = jnp.searchsorted(e_sorted, jnp.arange(E, dtype=flat_e.dtype))
    pos_sorted = (jnp.arange(T * k, dtype=jnp.int32)
                  - starts.astype(jnp.int32)[e_sorted])
    flat_pos = jnp.zeros((T * k,), jnp.int32).at[order].set(pos_sorted)
    keep = flat_pos < C
    flat_tok = jnp.arange(T * k, dtype=jnp.int32) // k

    # dispatch: (E, C) slot -> token index (gather beats scatter-add on TPU)
    tok_for_slot = jnp.zeros((E, C), jnp.int32).at[flat_e, flat_pos].set(
        flat_tok, mode="drop"
    )
    slot_valid = jnp.zeros((E, C), bool).at[flat_e, flat_pos].set(keep, mode="drop")
    expert_in = jnp.take(xf, tok_for_slot, axis=0) * slot_valid[..., None].astype(x.dtype)
    expert_in = _constrain(expert_in, _expert_spec(cfg, e_dim=0))

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", expert_in, p["wg"])) * jnp.einsum(
        "ecd,edf->ecf", expert_in, p["wu"]
    )
    h = _constrain(h, _expert_spec(cfg, e_dim=0, hidden_dim=2))
    out_e = jnp.einsum("ecf,efd->ecd", h, p["wd"])             # (E, C, D)
    out_e = _constrain(out_e, _expert_spec(cfg, e_dim=0))

    # combine: gather each (token, slot)'s expert output back
    gathered = out_e[flat_e, flat_pos]                         # (T*k, D)
    w = (gate.reshape(T * k) * keep.astype(jnp.float32)).astype(x.dtype)
    y = jnp.sum((gathered * w[:, None]).reshape(T, k, D), axis=1)
    return y.reshape(B, S, D), aux
