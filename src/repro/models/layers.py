"""Shared building blocks: norms, MLPs, rotary embeddings, initializers."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Params = Dict[str, jax.Array]


# --------------------------------------------------------------------------- #
# initialisation
# --------------------------------------------------------------------------- #
def dense_init(key, shape, dtype, scale: float = 1.0):
    """Variance-scaling (fan-in) truncated-normal init."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale / jnp.sqrt(jnp.asarray(fan_in, jnp.float32))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# --------------------------------------------------------------------------- #
# norms
# --------------------------------------------------------------------------- #
def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    # (1 + scale) parameterisation (gemma/qwen style): init scale = 0 ≡ identity
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32)) + bias.astype(jnp.float32)).astype(dt)


def init_norm(cfg: ModelConfig, d: int) -> Params:
    p = {"scale": jnp.zeros((d,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_norm(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    if cfg.norm == "layernorm":
        return layernorm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rmsnorm(x, p["scale"], cfg.norm_eps)


# --------------------------------------------------------------------------- #
# MLP
# --------------------------------------------------------------------------- #
def init_mlp(key, cfg: ModelConfig, d_ff: int, dtype) -> Params:
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    if cfg.activation in ("swiglu", "geglu"):
        return {
            "wg": dense_init(ks[0], (d, d_ff), dtype),
            "wu": dense_init(ks[1], (d, d_ff), dtype),
            "wd": dense_init(ks[2], (d_ff, d), dtype),
        }
    return {
        "wu": dense_init(ks[0], (d, d_ff), dtype),
        "wd": dense_init(ks[1], (d_ff, d), dtype),
    }


def apply_mlp(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    if cfg.activation == "swiglu":
        h = jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])
    elif cfg.activation == "geglu":
        h = jax.nn.gelu(x @ p["wg"], approximate=True) * (x @ p["wu"])
    else:
        h = jax.nn.gelu(x @ p["wu"], approximate=True)
    return h @ p["wd"]


# --------------------------------------------------------------------------- #
# rotary position embeddings
# --------------------------------------------------------------------------- #
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                 # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)
