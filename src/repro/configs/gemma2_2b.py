"""gemma2-2b — dense, local+global alternating attention, logit softcap
[arXiv:2408.00118].

26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000, head_dim=256,
4096-token sliding window on local (even) layers, attention softcap 50,
final-logit softcap 30, GeGLU MLP, pre+post RMSNorm.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    arch_type="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256_000,
    window=4096,
    layer_pattern="local_global",
    attn_softcap=50.0,
    final_softcap=30.0,
    post_norms=True,
    activation="geglu",
    tie_embeddings=True,
    rope_theta=10000.0,
    grad_accum=8,
    source="arXiv:2408.00118",
)
