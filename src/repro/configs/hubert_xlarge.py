"""hubert-xlarge — encoder-only audio transformer (w2v2 arch) [arXiv:2106.07447].

48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504 (masked-unit targets).
Per the brief the conv feature extractor is a STUB: ``input_specs`` provides
precomputed frame embeddings (B, T, 1280); we implement the transformer
encoder (bidirectional, no decode shapes).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    arch_type="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    encoder_only=True,
    frontend="audio",
    activation="gelu",
    norm="layernorm",
    grad_accum=8,
    source="arXiv:2106.07447",
)
