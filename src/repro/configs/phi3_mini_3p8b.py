"""phi3-mini-3.8b — dense, RoPE SwiGLU GQA [arXiv:2404.14219].

32L d_model=3072 32H (GQA kv=32 == MHA) d_ff=8192 vocab=32064, head_dim=96.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b",
    arch_type="dense",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    activation="swiglu",
    rope_theta=10000.0,
    grad_accum=8,
    source="arXiv:2404.14219",
)
