"""Architecture registry: ``--arch <id>`` ids map to published configs."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (  # noqa: F401 (re-export)
    ModelConfig,
    ShapeConfig,
    SHAPES,
    config_for_shape,
    shape_applicable,
)

_MODULES = {
    "hymba-1.5b": "repro.configs.hymba_1p5b",
    "gemma2-2b": "repro.configs.gemma2_2b",
    "pixtral-12b": "repro.configs.pixtral_12b",
    "phi3-mini-3.8b": "repro.configs.phi3_mini_3p8b",
    "falcon-mamba-7b": "repro.configs.falcon_mamba_7b",
    "starcoder2-3b": "repro.configs.starcoder2_3b",
    "arctic-480b": "repro.configs.arctic_480b",
    "qwen3-14b": "repro.configs.qwen3_14b",
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b_a22b",
    "hubert-xlarge": "repro.configs.hubert_xlarge",
}

ARCH_IDS: List[str] = list(_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return importlib.import_module(_MODULES[arch_id]).CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
