"""hymba-1.5b — hybrid parallel attention+mamba heads [arXiv:2411.13676].

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Hymba runs attention heads and mamba heads in parallel within each layer and
fuses the branch outputs after per-branch normalization; most layers use
sliding-window attention, with full attention on the first/middle/last layers.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    arch_type="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    ssm_state=16,
    ssm_expand=2,
    window=1024,
    layer_pattern="hymba",
    activation="swiglu",
    rope_theta=10000.0,
    grad_accum=8,
    ssm_chunk=2048,
    source="arXiv:2411.13676",
)
