"""Config system: model architecture configs + benchmark input shapes.

Every assigned architecture gets one module in this package defining a
``ModelConfig`` with the exact published dimensions (source cited in the
module docstring).  ``reduced()`` derives the CPU-smoke-test variant
(<=2 layers, d_model<=512, <=4 experts) of the same family.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description consumed by ``repro.models.transformer``."""

    name: str
    arch_type: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- attention features -------------------------------------------------
    rope_theta: float = 10000.0
    qk_norm: bool = False                 # qwen3-style per-head RMSNorm on q,k
    attn_softcap: Optional[float] = None  # gemma2 attention logit soft-capping
    final_softcap: Optional[float] = None  # gemma2 final-logit soft-capping
    window: Optional[int] = None          # sliding-window size for local layers
    # layer attention pattern: 'global' (all full), 'local' (all windowed),
    # 'local_global' (alternating, local first — gemma2), or
    # 'hymba' (all local except first/middle/last global)
    layer_pattern: str = "global"
    post_norms: bool = False              # gemma2 post-attn/post-mlp norms
    activation: str = "swiglu"            # swiglu | geglu | gelu
    norm: str = "rmsnorm"                 # rmsnorm | layernorm
    encoder_only: bool = False            # hubert: bidirectional, no decode
    tie_embeddings: bool = False
    norm_eps: float = 1e-6

    # --- mixture of experts --------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    moe_dense_residual: bool = False      # arctic: dense MLP in parallel w/ MoE
    dense_d_ff: int = 0                   # arctic dense-residual hidden size
    capacity_factor: float = 1.25
    # 'tensor': expert FFN hidden dim sharded on model axis
    # 'expert': expert dim sharded on model axis (expert parallelism)
    moe_sharding: str = "tensor"

    # --- state space (mamba1) ------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    dt_rank: int = 0                      # 0 -> d_model // 16
    ssm_chunk: int = 0                    # 0 -> single associative scan

    # --- modality frontend (stub per the brief) ------------------------------
    frontend: str = "none"                # none | audio | vision
    n_patches: int = 0                    # vlm: image patch embeddings per seq

    # --- misc -----------------------------------------------------------------
    long_context: bool = False  # force windowed attention everywhere (long_500k)
    dtype: str = "bfloat16"
    remat: bool = True
    # unroll the layer scan (used by the dry-run's depth-point lowerings so
    # cost_analysis sees every layer; full-depth lowerings keep the scan)
    scan_unroll: bool = False
    # cross-entropy vocab chunking (0 = auto: chunk when vocab >= 16384;
    # <0 = force dense).  Bounds live logits memory to B*S*8192 — large-vocab
    # archs cannot fit dense fp32 logits + grads in HBM at assigned batches.
    ce_chunk: int = 0
    # query-chunked attention (0 = dense masked attention).  Dense attention
    # materializes (B,H,Sq,Sk) fp32 scores — 34 GB/device for phi3 train_4k —
    # so the production default streams query blocks of this size.
    attn_chunk: int = 256
    # gradient accumulation (microbatches per step).  The backward-over-scan
    # residual stack is n_layers * tokens_mb * d_model * ~4B per device;
    # accumulation bounds it.  Must divide the per-device batch.
    grad_accum: int = 1
    # ZeRO/FSDP-style weight sharding over the data axis, on top of model-axis
    # tensor parallelism.  Needed by the MoE giants (arctic: 960 GB bf16).
    # With fsdp=True a "worker" (the paper's m) is a full data x model slice,
    # so the ZO step's worker axis becomes the pod axis (see DESIGN.md §3).
    fsdp: bool = False
    # dispatch sequence mixing to the Pallas TPU kernels (flash attention /
    # selective scan).  Requires static windows (uniform or full) and
    # kernel-aligned shapes; used on real TPU runtimes and in interpret-mode
    # equivalence tests — the CPU dry-run lowers the jnp path.
    use_pallas: bool = False
    source: str = ""                      # citation

    # ------------------------------------------------------------------------
    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank_actual(self) -> int:
        return self.dt_rank if self.dt_rank > 0 else max(1, self.d_model // 16)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def has_attention(self) -> bool:
        return self.arch_type != "ssm"

    @property
    def has_ssm(self) -> bool:
        return self.arch_type in ("ssm", "hybrid")

    @property
    def pattern_period(self) -> int:
        """Layers are scanned in homogeneous groups of this many layers."""
        return 2 if self.layer_pattern == "local_global" else 1

    @property
    def n_groups(self) -> int:
        assert self.n_layers % self.pattern_period == 0
        return self.n_layers // self.pattern_period

    def layer_windows(self) -> Tuple[Optional[int], ...]:
        """Static per-layer window (None = full attention) before long_context."""
        if not self.has_attention:
            return tuple([None] * self.n_layers)
        if self.long_context and self.window:
            return tuple([self.window] * self.n_layers)
        if self.layer_pattern == "global":
            return tuple([None] * self.n_layers)
        if self.layer_pattern == "local":
            return tuple([self.window] * self.n_layers)
        if self.layer_pattern == "local_global":
            return tuple(
                self.window if i % 2 == 0 else None for i in range(self.n_layers)
            )
        if self.layer_pattern == "hymba":
            glb = {0, self.n_layers // 2, self.n_layers - 1}
            return tuple(
                None if i in glb else self.window for i in range(self.n_layers)
            )
        raise ValueError(self.layer_pattern)

    @property
    def subquadratic(self) -> bool:
        """True when every layer's sequence mixing is sub-quadratic in seq."""
        if self.arch_type == "ssm":
            return True
        return all(w is not None for w in self.layer_windows())

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant of the same family (brief: <=2L, d<=512, <=4e)."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        while n_heads % n_kv:
            n_kv -= 1
        period = self.pattern_period
        return self.with_(
            name=self.name + "-reduced",
            n_layers=2 * period if period > 1 else 2,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=32,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            dense_d_ff=min(self.dense_d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            window=min(self.window, 8) if self.window else None,
            dt_rank=8 if self.has_ssm else 0,
            n_patches=min(self.n_patches, 4),
            dtype="float32",
            grad_accum=1,
            fsdp=False,
            ssm_chunk=0,
        )

    # --- analytic parameter count (for MODEL_FLOPS = 6*N*D) ------------------
    def param_count(self, active_only: bool = False) -> int:
        d, f, hd = self.d_model, self.d_ff, self.head_dim
        h, kv = self.n_heads, self.n_kv_heads
        n = 0
        if self.frontend != "audio":
            n += self.vocab_size * d                       # embed
        if not self.tie_embeddings:
            n += d * self.vocab_size                       # head
        per_layer = 0
        if self.has_attention:
            per_layer += d * h * hd + 2 * d * kv * hd + h * hd * d
            if self.qk_norm:
                per_layer += 2 * hd
        if self.has_ssm:
            di, dtr, ns = self.d_inner, self.dt_rank_actual, self.ssm_state
            per_layer += d * 2 * di + di * self.ssm_conv + di
            per_layer += di * (dtr + 2 * ns) + dtr * di + di
            per_layer += di * ns + di + di * d
        if self.is_moe:
            per_layer += d * self.n_experts                # router
            e = self.top_k if active_only else self.n_experts
            per_layer += e * 3 * d * f                     # swiglu experts
            if self.moe_dense_residual:
                per_layer += 3 * d * self.dense_d_ff
        elif f:
            mult = 3 if self.activation in ("swiglu", "geglu") else 2
            per_layer += mult * d * f
        per_layer += 2 * d                                 # norms
        if self.post_norms:
            per_layer += 2 * d
        n += self.n_layers * per_layer
        n += d                                             # final norm
        return n


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Brief's skip rules. Returns (applicable, reason-if-not)."""
    if cfg.encoder_only and shape.kind == "decode":
        return False, "encoder-only architecture has no decode step"
    if shape.name == "long_500k":
        lc = cfg if cfg.subquadratic else cfg.with_(long_context=True)
        if not lc.subquadratic:
            return False, "pure full-attention arch without sliding-window variant"
    return True, ""


def config_for_shape(cfg: ModelConfig, shape: ShapeConfig) -> ModelConfig:
    """long_500k uses the sliding-window long-context variant where needed."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return cfg.with_(long_context=True, name=cfg.name + "+swa")
    return cfg
