"""arctic-480b — dense-MoE hybrid: 128 experts top-2 + dense residual MLP
[hf:Snowflake/snowflake-arctic-base].

35L d_model=7168 56H (GQA kv=8) d_ff=4864 (per-expert) vocab=32000,
head_dim=128. Arctic composes a small dense residual MLP in parallel with
the top-2-of-128 MoE FFN.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    arch_type="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32000,
    n_experts=128,
    top_k=2,
    moe_dense_residual=True,
    dense_d_ff=4864,
    activation="swiglu",
    rope_theta=10000.0,
    fsdp=True,
    grad_accum=16,
    source="hf:Snowflake/snowflake-arctic-base",
)
