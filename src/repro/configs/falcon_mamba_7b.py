"""falcon-mamba-7b — attention-free mamba1 SSM [arXiv:2410.05355].

64L d_model=4096 (no attention) vocab=65024, ssm_state=16, expand=2
(d_inner=8192), conv kernel 4, dt_rank=d_model/16=256.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    arch_type="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=65024,
    ssm_state=16,
    ssm_expand=2,
    ssm_conv=4,
    dt_rank=256,
    tie_embeddings=True,
    grad_accum=16,
    ssm_chunk=1024,
    source="arXiv:2410.05355",
)
