"""qwen3-moe-235b-a22b — MoE, 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B family].

94L d_model=4096 64H (GQA kv=4) d_ff=1536 (per-expert) vocab=151936,
head_dim=128, qk_norm.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    arch_type="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab_size=151_936,
    n_experts=128,
    top_k=8,
    qk_norm=True,
    activation="swiglu",
    rope_theta=1_000_000.0,
    fsdp=True,
    grad_accum=16,
    source="hf:Qwen/Qwen3-30B-A3B",
)
