"""pixtral-12b — VLM: pixtral-ViT frontend + mistral-nemo decoder
[hf:mistralai/Pixtral-12B-2409].

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072, head_dim=128.
Per the brief, the vision encoder is a STUB: ``input_specs`` provides
precomputed patch embeddings (B, n_patches, d_model) that the decoder
consumes as a sequence prefix ahead of the text tokens.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    arch_type="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131_072,
    frontend="vision",
    n_patches=1024,
    activation="swiglu",
    rope_theta=1_000_000.0,
    grad_accum=16,
    source="hf:mistralai/Pixtral-12B-2409",
)
