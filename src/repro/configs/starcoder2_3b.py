"""starcoder2-3b — dense, GQA + RoPE [arXiv:2402.19173].

30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152, head_dim=128,
non-gated GELU MLP, LayerNorm. StarCoder2 natively trains with a 4096-token
sliding window [arXiv:2402.19173 §4], which we use for the long-context
variant (long_500k).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    arch_type="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    head_dim=128,
    d_ff=12288,
    vocab_size=49152,
    window=4096,           # native SWA; pattern 'global' = full attn by default
    activation="gelu",
    norm="layernorm",
    rope_theta=100_000.0,
    grad_accum=8,
    source="arXiv:2402.19173",
)
