"""qwen3-14b — dense, qk_norm + GQA [hf:Qwen/Qwen3-8B family].

40L d_model=5120 40H (GQA kv=8) d_ff=17408 vocab=151936, head_dim=128,
per-head RMSNorm on q and k (qk_norm).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    arch_type="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=17408,
    vocab_size=151_936,
    qk_norm=True,
    activation="swiglu",
    rope_theta=1_000_000.0,
    grad_accum=16,
    source="hf:Qwen/Qwen3-8B",
)
