"""ShapeDtypeStruct input stand-ins + shardings for every (arch x shape).

``input_specs`` builds the exact abstract inputs each dry-run target takes —
weak-type-correct, shardable, zero allocation.  Decode shapes include the
full-length KV caches / SSM states; long_500k shards the cache *sequence*
over the worker axes (batch=1).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.dist.sharding import batch_specs, cache_specs, param_specs, worker_axes
from repro.models import transformer as T

Struct = jax.ShapeDtypeStruct


def _ns(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def abstract_params(cfg: ModelConfig) -> Any:
    return jax.eval_shape(lambda k: T.init_model(k, cfg), jax.random.key(0))


def train_batch_structs(cfg: ModelConfig, shape: ShapeConfig,
                        with_labels: bool = True) -> Dict[str, Struct]:
    B, S = shape.global_batch, shape.seq_len
    act = jnp.dtype(cfg.dtype)
    if cfg.frontend == "audio":
        b = {"features": Struct((B, S, cfg.d_model), act)}
        if with_labels:
            b["labels"] = Struct((B, S), jnp.int32)
        return b
    if cfg.frontend == "vision":
        Pn = cfg.n_patches
        assert S > Pn, (S, Pn)
        b = {
            "tokens": Struct((B, S - Pn), jnp.int32),
            "image_embeds": Struct((B, Pn, cfg.d_model), act),
        }
        if with_labels:
            b["labels"] = Struct((B, S), jnp.int32)   # -1 over the patch prefix
        return b
    b = {"tokens": Struct((B, S), jnp.int32)}
    if with_labels:
        b["labels"] = Struct((B, S), jnp.int32)
    return b


def decode_structs(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[Struct, Struct, Dict]:
    B, S = shape.global_batch, shape.seq_len
    act = jnp.dtype(cfg.dtype)
    caches = jax.eval_shape(lambda: T.init_caches(cfg, B, S, act))
    token = Struct((B,), jnp.int32)
    pos = Struct((), jnp.int32)
    return token, pos, caches


def input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                kind: str) -> Tuple[Tuple, Tuple]:
    """Returns (arg_structs, arg_shardings) for the given step kind.

    kind: 'train' -> (t, params, opt_state, batch)
          'prefill' -> (params, batch)
          'decode' -> (params, token, pos, caches)
    """
    pstruct = abstract_params(cfg)
    psharding = _ns(mesh, param_specs(cfg, pstruct, mesh))
    repl = NamedSharding(mesh, P())

    if kind == "train":
        batch = train_batch_structs(cfg, shape)
        args = (Struct((), jnp.int32), pstruct, (), batch)
        shardings = (repl, psharding, (), _ns(mesh, batch_specs(mesh, batch)))
        return args, shardings
    if kind == "prefill":
        batch = train_batch_structs(cfg, shape, with_labels=cfg.encoder_only)
        args = (pstruct, batch)
        shardings = (psharding, _ns(mesh, batch_specs(mesh, batch)))
        return args, shardings
    if kind == "decode":
        token, pos, caches = decode_structs(cfg, shape)
        seq_sharded = shape.name == "long_500k"
        csh = _ns(mesh, cache_specs(cfg, mesh, caches, seq_sharded))
        tok_sh = (
            repl if shape.global_batch % _workers(mesh) else
            NamedSharding(mesh, P(worker_axes(mesh)))
        )
        args = (pstruct, token, pos, caches)
        shardings = (psharding, tok_sh, repl, csh)
        return args, shardings
    raise ValueError(kind)


def _workers(mesh: Mesh) -> int:
    n = 1
    for a in worker_axes(mesh):
        n *= mesh.shape[a]
    return n
