"""Simulated-cluster training driver: loss vs simulated seconds.

Replays the real step functions through ``repro.sim``'s discrete-event
cluster model.  Example — HO-SGD vs sync-SGD on a bandwidth-starved link
with 10% stragglers:

    PYTHONPATH=src python -m repro.launch.sim --dataset acoustic \
        --methods ho_sgd sync_sgd --iters 400 --tau 8 \
        --bandwidth 1e5 --straggler-prob 0.1 --target-loss 0.9

Federated partial participation — 1024 clients, cohorts of 10 per round
with 90% availability, HO-SGD vs the FedAvg-family baselines:

    PYTHONPATH=src python -m repro.launch.sim --federated 1024:10 \
        --availability 0.9 --methods fed_ho_sgd fed_avg fed_dropout_avg \
        --batch 80 --iters 200 --tau 4
"""
from __future__ import annotations

import argparse
import json

import jax

from repro.core.ho_sgd import parse_tau_schedule
from repro.data.synthetic import batches, make_classification
from repro.dist import get_compressor
from repro.metrics import CSVLogger
from repro.models.mlp import init_mlp_classifier, mlp_loss
from repro.sim import (
    COLLECTIVE_KINDS,
    ClusterSpec,
    Topology,
    compute_model_for,
    make_sim_methods,
    simulate,
)

METHODS = ["ho_sgd", "ho_sgd_adaptive", "sync_sgd", "zo_sgd", "pa_sgd",
           "pa_gossip", "ri_sgd", "qsgd", "fed_ho_sgd", "fed_avg",
           "fed_dropout_avg"]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="acoustic",
                    choices=["sensorless", "acoustic", "covtype", "seismic"])
    ap.add_argument("--hidden", type=int, default=32,
                    help="MLP hidden width (controls d)")
    ap.add_argument("--methods", nargs="*", default=["ho_sgd", "sync_sgd"],
                    choices=METHODS)
    ap.add_argument("--iters", type=int, default=400)
    ap.add_argument("--batch", type=int, default=64, help="global batch (m*B)")
    ap.add_argument("--tau", type=int, default=8)
    ap.add_argument("--tau-schedule", default=None,
                    help="'const:K' | 'linear:start,end,horizon' for "
                         "ho_sgd_adaptive (default: linear ramp to --tau)")
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--zo-lr", type=float, default=None)
    ap.add_argument("--mu", type=float, default=1e-3)
    ap.add_argument("--compress", default="none",
                    choices=["none", "qsgd", "signsgd", "topk"])
    ap.add_argument("--compress-mode", default="per_worker",
                    choices=["per_worker", "legacy"],
                    help="per_worker: faithful per-worker encode + server "
                         "decode (wire bytes = nbytes x live workers); "
                         "legacy: post-reduction decode(encode(mean))")
    ap.add_argument("--replay", default="per_worker",
                    choices=["per_worker", "monolithic"],
                    help="per_worker replays rounds at the live membership "
                         "and each worker's actual params view; monolithic "
                         "keeps the PR-4 pricing-only replay")
    ap.add_argument("--seed", type=int, default=0)
    # federated partial participation
    ap.add_argument("--federated", default=None, metavar="N:K",
                    help="client-sampling rounds: N total clients, seeded "
                         "cohorts of K per round (sets n_clients/cohort_k "
                         "and overrides --m with K); use with the fed_* "
                         "methods")
    ap.add_argument("--availability", type=float, default=1.0,
                    help="per-round probability a sampled client shows up "
                         "(federated churn; at least one survivor)")
    ap.add_argument("--local-steps", type=int, default=None,
                    help="fed_avg/fed_dropout_avg local SGD steps per round "
                         "(default: --tau)")
    ap.add_argument("--fed-dropout", type=float, default=0.25,
                    help="fed_dropout_avg: fraction of each client upload "
                         "zeroed (masked out) per round")
    # cluster
    ap.add_argument("--m", type=int, default=4)
    ap.add_argument("--flops", type=float, default=1e9,
                    help="per-worker FLOP/s")
    ap.add_argument("--bandwidth", type=float, default=1e6, help="bytes/s")
    ap.add_argument("--alpha", type=float, default=1e-5,
                    help="per-collective latency (s)")
    ap.add_argument("--collective", default="flat",
                    choices=list(COLLECTIVE_KINDS),
                    help="all-reduce algorithm (alpha-beta round structure)")
    ap.add_argument("--pods", type=int, default=1,
                    help=">1 prices a hierarchical reduce: intra-pod "
                         "--collective + inter-pod ring on the slow link")
    ap.add_argument("--inter-alpha", type=float, default=1e-3,
                    help="inter-pod latency per collective (s)")
    ap.add_argument("--inter-bandwidth", type=float, default=1e8,
                    help="inter-pod bytes/s")
    ap.add_argument("--max-staleness", type=int, default=0,
                    help=">0 runs ZO rounds unbarriered, each worker at "
                         "most this many rounds ahead (FO syncs barrier)")
    ap.add_argument("--elastic", action="store_true",
                    help="failures shrink the membership (no rollback); "
                         "workers rejoin via a checkpoint round-trip")
    ap.add_argument("--downtime", type=float, default=60.0,
                    help="mean elastic rejoin delay (s, exponential)")
    ap.add_argument("--straggler-prob", type=float, default=0.0)
    ap.add_argument("--straggler-slowdown", type=float, default=4.0)
    ap.add_argument("--jitter", type=float, default=0.0,
                    help="lognormal sigma on per-iteration compute time")
    ap.add_argument("--fail-rate", type=float, default=0.0,
                    help="failures per simulated second")
    ap.add_argument("--restart-time", type=float, default=30.0)
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="sim-checkpoint period (iterations); required >0 "
                         "when --fail-rate > 0")
    # overlap / contention (latency-honest rounds)
    ap.add_argument("--overlap-buckets", type=int, default=1,
                    help="bucket the HO-family collectives for compute/comm "
                         "overlap: only the exposed tail of the collective "
                         "is priced (costs.exposed_comm_time); 1 = strict "
                         "compute-then-communicate.  Bytes never change.")
    ap.add_argument("--no-contention", action="store_true",
                    help="price concurrent async exchanges independently "
                         "instead of serializing them on shared per-pod/"
                         "inter-pod links (events.LinkContention)")
    # output
    ap.add_argument("--target-loss", type=float, default=None)
    ap.add_argument("--eval-every", type=int, default=5)
    ap.add_argument("--log", default=None, help="CSV path")
    ap.add_argument("--json", default=None, help="summary JSON path")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="write a Perfetto trace of each method's simulated "
                         "run (one lane per worker; multi-method runs get "
                         "OUT.METHOD.json) and print its attribution")
    args = ap.parse_args(argv)

    n_clients = cohort_k = 0
    if args.federated:
        n_str, _, k_str = args.federated.partition(":")
        n_clients, cohort_k = int(n_str), int(k_str)
        assert cohort_k >= 1, "--federated N:K needs K >= 1"
        args.m = cohort_k    # the sim's worker slots hold the cohort

    topo = (Topology(pods=args.pods, inter_alpha=args.inter_alpha,
                     inter_bandwidth=args.inter_bandwidth)
            if args.pods > 1 else None)
    cluster = ClusterSpec(
        m=args.m, flops_per_sec=args.flops, alpha=args.alpha,
        bandwidth=args.bandwidth, collective=args.collective, topology=topo,
        max_staleness=args.max_staleness, straggler_prob=args.straggler_prob,
        straggler_slowdown=args.straggler_slowdown, jitter_sigma=args.jitter,
        fail_rate=args.fail_rate, elastic=args.elastic,
        downtime=args.downtime, restart_time=args.restart_time,
        ckpt_every=args.ckpt_every, contention=not args.no_contention,
        n_clients=n_clients, cohort_k=cohort_k,
        availability=args.availability, seed=args.seed)

    ds = make_classification(args.dataset, seed=args.seed)
    params = init_mlp_classifier(jax.random.key(args.seed), ds.n_features,
                                 ds.n_classes, hidden=args.hidden)
    d = sum(int(x.size) for x in jax.tree.leaves(params))
    assert args.batch % cluster.m == 0, "--batch must divide by --m"
    compute = compute_model_for(params, cluster, args.batch // cluster.m)
    eval_batch = {"x": ds.x_test, "y": ds.y_test}
    eval_fn = jax.jit(lambda p: mlp_loss(p, eval_batch))

    sched = (parse_tau_schedule(args.tau_schedule)
             if args.tau_schedule else None)
    sims = make_sim_methods(
        mlp_loss, params, cluster, tau=args.tau, lr=args.lr, zo_lr=args.zo_lr,
        mu=args.mu, seed=args.seed, codec=get_compressor(args.compress),
        compress_mode=args.compress_mode, tau_schedule=sched,
        which=args.methods, overlap_buckets=args.overlap_buckets,
        local_steps=args.local_steps, fed_dropout=args.fed_dropout)

    print(f"sim: dataset={args.dataset} d={d:,} m={cluster.m} "
          f"bandwidth={cluster.bandwidth:.3g}B/s alpha={cluster.alpha:.3g}s "
          f"flops={cluster.flops_per_sec:.3g}/s seed={cluster.seed} "
          f"collective={cluster.collective} pods={args.pods} "
          f"staleness={cluster.max_staleness} elastic={cluster.elastic} "
          f"replay={args.replay} compress_mode={args.compress_mode} "
          f"overlap_buckets={args.overlap_buckets} "
          f"contention={cluster.contention}"
          + (f" federated={cluster.n_clients}:{cluster.cohort_k} "
             f"availability={cluster.availability}"
             if cluster.n_clients else ""))
    summaries = {}
    with CSVLogger(args.log, ["method", "iter", "order", "loss", "t_sim",
                              "comm_bytes"]) as logger:
        for name, sm in sims.items():
            res = simulate(
                sm, params, batches(ds, args.batch, seed=args.seed), cluster,
                args.iters, compute=compute, eval_fn=eval_fn,
                eval_every=args.eval_every, target_loss=args.target_loss,
                replay=args.replay)
            for i in range(len(res.steps)):
                logger.log(method=name, iter=res.steps[i],
                           order=res.orders[i], loss=res.losses[i],
                           t_sim=res.times[i], comm_bytes=res.comm_bytes[i])
            if args.trace:
                from repro.obs import attribution, format_report, write_trace
                path = args.trace if len(sims) == 1 else \
                    args.trace.replace(".json", f".{name}.json")
                write_trace(path, res.spans, title=f"sim:{name}")
                for line in format_report(attribution(res.spans),
                                          title=f"trace/{name}"):
                    print(line)
                print("wrote", path)
            s = res.summary()
            if args.target_loss is not None:
                s["t_to_target"] = res.time_to_loss(args.target_loss)
                s["feval_s_to_target"] = res.feval_seconds_to_loss(
                    args.target_loss)
            summaries[name] = s
            parts = [f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
                     for k, v in s.items() if k != "name"]
            print(f"sim/{name}: " + " ".join(parts))
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"cluster": vars(args), "results": summaries}, f,
                      indent=1)
        print("wrote", args.json)
    return summaries


if __name__ == "__main__":
    main()
