"""Serving driver: offline batch generate or open-loop Poisson traffic.

Offline (default): submit a batch of random prompts to the continuous-
batching engine, print completions and measured tok/s.

Traffic (``--traffic poisson:RATE[,MIX]``): replay a seeded open-loop
workload (``repro.sim.traffic``) against the engine, price every scheduler
step with the training-side ``ComputeModel``, and report tokens/sec and
p50/p99 TTFT/latency.  ``--log`` writes one CSV row per request
(arrival/ttft/latency) through the context-managed ``CSVLogger``, as the
train/sim CLIs do.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.launch.train import size_override
from repro.metrics import CSVLogger
from repro.models import transformer as T
from repro.serving import Engine, ServeConfig
from repro.sim.traffic import (
    MIXES,
    TrafficSpec,
    replay,
    replay_seed_sync,
    serve_compute_model,
)


def parse_traffic(arg: str, n_requests: int, seed: int, vocab: int) -> TrafficSpec:
    """``poisson:RATE[,MIX]`` -> TrafficSpec (MIX one of repro.sim.traffic.MIXES)."""
    kind, _, rest = arg.partition(":")
    if kind != "poisson" or not rest:
        raise SystemExit(f"unknown --traffic {arg!r}; want poisson:RATE[,MIX]")
    rate_s, _, mix = rest.partition(",")
    mix = mix or "mixed"
    if mix not in MIXES:
        raise SystemExit(f"unknown traffic mix {mix!r}; have {sorted(MIXES)}")
    return TrafficSpec.from_mix(rate=float(rate_s), n_requests=n_requests,
                                mix=mix, seed=seed, vocab=vocab)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b", choices=ARCH_IDS)
    ap.add_argument("--reduce", default="smoke", choices=["full", "100m", "smoke"])
    ap.add_argument("--batch", type=int, default=4,
                    help="offline: number of prompts; traffic: n_requests "
                         "(use --requests to override)")
    ap.add_argument("--requests", type=int, default=None,
                    help="traffic mode: number of arrivals (default --batch)")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--slots", type=int, default=8,
                    help="KV-cache slot pool size (max decode batch)")
    ap.add_argument("--eos-id", type=int, default=-1,
                    help="stop a request when it emits this token (-1 = off)")
    ap.add_argument("--traffic", default=None,
                    help="open-loop workload, e.g. poisson:50.0,mixed")
    ap.add_argument("--flops-per-sec", type=float, default=1e12,
                    help="traffic mode: simulated accelerator throughput")
    ap.add_argument("--log", default=None,
                    help="CSV path for per-request latency rows")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="traffic mode: write a Perfetto trace of the "
                         "replay (one lane per serving slot)")
    args = ap.parse_args(argv)

    cfg = size_override(get_config(args.arch), args.reduce)
    if cfg.encoder_only or cfg.frontend != "none":
        raise SystemExit("choose a text decoder arch for serving")
    params = T.init_model(jax.random.key(args.seed), cfg)

    if args.traffic:
        spec = parse_traffic(args.traffic, args.requests or args.batch,
                             args.seed, cfg.vocab_size)
        eng = Engine(cfg, params, ServeConfig(
            max_seq=spec.required_max_seq(), temperature=args.temperature,
            eos_id=args.eos_id, slots=args.slots),
            key=jax.random.key(args.seed) if args.temperature > 0 else None)
        cm = serve_compute_model(cfg, args.flops_per_sec)
        tracer = None
        if args.trace:
            from repro.obs import Tracer
            tracer = Tracer(clock="sim")
        res = replay(eng, spec, cm, tracer=tracer)
        sync = replay_seed_sync(spec, cm, batch=args.slots)
        fields = ["rid", "arrival", "prompt_len", "max_new", "ttft",
                  "queue_s", "service_s", "latency", "finish"]
        with CSVLogger(args.log, fields) as log:
            for row in res.rows:
                log.log(**row)
        if tracer is not None:
            from repro.obs import write_trace
            write_trace(args.trace, tracer, title=f"serve:{args.traffic}")
            print(f"wrote trace {args.trace} ({len(tracer.spans)} spans)")
        s = res.summary
        print(f"traffic {args.traffic}: {int(s['n_requests'])} requests, "
              f"{int(s['total_tokens'])} tokens in {s['makespan_s']:.3f} sim-s "
              f"({s['tok_per_sec']:.1f} tok/s; wall {res.wall_s:.2f}s)")
        print(f"  ttft    p50 {s['p50_ttft_s']*1e3:.1f} ms   "
              f"p99 {s['p99_ttft_s']*1e3:.1f} ms   (queue p99 "
              f"{s['p99_queue_s']*1e3:.1f} ms + service p99 "
              f"{s['p99_service_s']*1e3:.1f} ms)")
        print(f"  latency p50 {s['p50_latency_s']*1e3:.1f} ms   "
              f"p99 {s['p99_latency_s']*1e3:.1f} ms")
        print(f"  seed-sync baseline (batch={args.slots}): "
              f"{sync.summary['tok_per_sec']:.1f} tok/s, "
              f"p99 latency {sync.summary['p99_latency_s']*1e3:.1f} ms")
        return

    eng = Engine(cfg, params, ServeConfig(
        max_seq=args.prompt_len + args.max_new, temperature=args.temperature,
        eos_id=args.eos_id, slots=args.slots))
    rng = np.random.default_rng(args.seed)
    prompts = [
        list(rng.integers(0, cfg.vocab_size, rng.integers(4, args.prompt_len + 1)))
        for _ in range(args.batch)
    ]
    t0 = time.perf_counter()
    outs = eng.generate(prompts, args.max_new, key=jax.random.key(args.seed))
    dt = time.perf_counter() - t0
    fields = ["rid", "prompt_len", "generated", "tokens"]
    with CSVLogger(args.log, fields) as log:
        n_tokens = 0
        for i, o in enumerate(outs):
            gen = o[len(prompts[i]):]
            n_tokens += len(gen)
            print(f"req{i}: prompt_len={len(prompts[i])} -> {gen}")
            log.log(rid=i, prompt_len=len(prompts[i]), generated=len(gen),
                    tokens=" ".join(map(str, gen)))
    tps = n_tokens / dt
    print(f"decoded {n_tokens} tokens over {args.slots} slots in {dt:.2f}s "
          f"({tps:.1f} tok/s)")


if __name__ == "__main__":
    main()
