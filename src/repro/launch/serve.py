"""Batched serving driver: prefill a batch of prompts, decode new tokens."""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.launch.train import size_override
from repro.models import transformer as T
from repro.serving import Engine, ServeConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b", choices=ARCH_IDS)
    ap.add_argument("--reduce", default="smoke", choices=["full", "100m", "smoke"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = size_override(get_config(args.arch), args.reduce)
    if cfg.encoder_only or cfg.frontend != "none":
        raise SystemExit("choose a text decoder arch for serving")
    params = T.init_model(jax.random.key(args.seed), cfg)
    eng = Engine(cfg, params, ServeConfig(
        max_seq=args.prompt_len + args.max_new, temperature=args.temperature))

    rng = np.random.default_rng(args.seed)
    prompts = [
        list(rng.integers(0, cfg.vocab_size, rng.integers(4, args.prompt_len + 1)))
        for _ in range(args.batch)
    ]
    t0 = time.perf_counter()
    outs = eng.generate(prompts, args.max_new, key=jax.random.key(args.seed))
    dt = time.perf_counter() - t0
    for i, o in enumerate(outs):
        print(f"req{i}: prompt_len={len(prompts[i])} -> {o[len(prompts[i]):]}")
    tps = args.batch * args.max_new / dt
    print(f"decoded {args.batch}x{args.max_new} tokens in {dt:.2f}s ({tps:.1f} tok/s)")


if __name__ == "__main__":
    main()
