from repro.launch import dryrun_flags  # noqa: F401  (must precede any jax import)

# Multi-pod dry-run: lower + compile every (arch x input-shape x mesh).
#
# For each target this records, as JSON under --out:
#   * compiled memory analysis (proves the program fits),
#   * cost analysis (FLOPs / bytes), scan-corrected via depth extrapolation,
#   * collective bytes by kind parsed from the compiled HLO,
#   * lower/compile wall times.
#
# Step kinds per shape: train_4k lowers the HO-SGD FO step (and the ZO step —
# the paper's technique — so the collective-load difference is visible);
# prefill_32k lowers ``prefill`` (plain forward for encoder-only archs);
# decode shapes lower ``serve_step`` (one token against a full KV cache).

import argparse
import json
import os
import time
from typing import Dict, Optional, Tuple

import jax

from repro import compat
from repro.configs import (
    ARCH_IDS, SHAPES, config_for_shape, get_config, shape_applicable,
)
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.distributed import make_fo_step, make_zo_step
from repro.core.ho_sgd import HOSGDConfig
from repro.dist.sharding import param_specs
from repro.launch import hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs
from repro.models import transformer as T
from repro.opt.optimizers import const_schedule, sgd
from repro.serving.engine import serve_step


def step_kinds(shape: ShapeConfig) -> Tuple[str, ...]:
    if shape.kind == "train":
        return ("fo", "zo")
    return (shape.kind,)  # prefill | decode


def build_target(cfg: ModelConfig, shape: ShapeConfig, mesh, step: str):
    """Returns (jitted_fn, arg_structs)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    if step in ("fo", "zo"):
        loss_fn = lambda p, b: T.loss_fn(cfg, p, b)
        opt = sgd(const_schedule(1e-2))
        args, shardings = input_specs(cfg, shape, mesh, "train")
        if step == "fo":
            fn = make_fo_step(loss_fn, mesh, opt, grad_accum=cfg.grad_accum,
                              scan_unroll=cfg.scan_unroll)
        else:
            from repro.launch.specs import abstract_params
            ho = HOSGDConfig(tau=8, mu=1e-3, lr=1e-2, zo_lr=1e-2 / 1e6,
                             acc_dtype=os.environ.get(
                                 "REPRO_ZO_ACC_DTYPE", "float32"))
            fn = make_zo_step(loss_fn, mesh, ho, opt, fsdp=cfg.fsdp,
                              param_specs_tree=param_specs(
                                  cfg, abstract_params(cfg), mesh))
        pshard = shardings[1]
        out_sh = (pshard, (), NamedSharding(mesh, P()))
        jf = jax.jit(fn, in_shardings=shardings, out_shardings=out_sh)
        return jf, args
    if step == "prefill":
        args, shardings = input_specs(cfg, shape, mesh, "prefill")
        if cfg.encoder_only:
            fn = lambda p, b: T.forward_logits(cfg, p, b)[0]
            jf = jax.jit(fn, in_shardings=shardings)
        else:
            fn = lambda p, b: T.prefill(cfg, p, b)
            # prefill returns the filled caches: pin their output shardings
            # (batch over workers + kv-head/hd over model) or they'd be
            # left to the compiler and could come back replicated
            from repro.dist.sharding import cache_specs
            from repro.launch.specs import decode_structs
            _, _, cstructs = decode_structs(cfg, shape)
            csh = jax.tree.map(
                lambda s: NamedSharding(mesh, s),
                cache_specs(cfg, mesh, cstructs, seq_sharded=False),
                is_leaf=lambda x: isinstance(x, P),
            )
            # prefill caches are dicts keyed like init_caches minus mamba? no:
            # prefill returns exactly the per-layer cache pytree shape
            jf = jax.jit(fn, in_shardings=shardings,
                         out_shardings=(None, csh))
        return jf, args
    if step == "decode":
        args, shardings = input_specs(cfg, shape, mesh, "decode")
        fn = lambda p, tok, pos, c: serve_step(cfg, p, tok, pos, c)
        # pin cache output shardings to the inputs (stable steady-state decode)
        jf = jax.jit(fn, in_shardings=shardings,
                     out_shardings=(None, shardings[3]))
        return jf, args
    raise ValueError(step)


def lower_compile(cfg, shape, mesh, step):
    jf, args = build_target(cfg, shape, mesh, step)
    t0 = time.perf_counter()
    lowered = jf.lower(*args)
    t1 = time.perf_counter()
    compiled = lowered.compile()
    t2 = time.perf_counter()
    return lowered, compiled, t1 - t0, t2 - t1


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch  # decode: one token


def run_one(arch: str, shape_name: str, multi_pod: bool, step: str,
            scan_correct: bool = True, verbose: bool = True,
            save_hlo: str = "") -> Dict:
    shape = SHAPES[shape_name]
    base = get_config(arch)
    ok, reason = shape_applicable(base, shape)
    mesh_name = "multipod" if multi_pod else "pod"
    rec: Dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "step": step,
        "applicable": ok, "skip_reason": reason,
    }
    if not ok:
        if verbose:
            print(f"[skip] {arch} x {shape_name} ({step}): {reason}")
        return rec

    cfg = config_for_shape(base, shape)
    tm = os.environ.get("REPRO_TEST_MESH")  # e.g. "4x2" / "2x2x2" (CI rehearsal)
    if tm:
        dims = tuple(int(x) for x in tm.split("x"))
        axes = ("pod", "data", "model") if len(dims) == 3 else ("data", "model")
        mesh = jax.make_mesh(dims, axes)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    p = cfg.pattern_period
    G = cfg.n_groups
    rec.update(n_layers=cfg.n_layers, period=p, n_groups=G,
               params=cfg.param_count(), params_active=cfg.param_count(True),
               model_flops=model_flops(cfg, shape))

    with compat.set_mesh(mesh):
        lowered, compiled, t_lower, t_compile = lower_compile(cfg, shape, mesh, step)
        rec["lower_s"] = round(t_lower, 2)
        rec["compile_s"] = round(t_compile, 2)
        rec["cost_raw"] = hlo.cost_summary(compiled)
        rec["memory"] = hlo.memory_summary(compiled)
        text = compiled.as_text()
        ms = mesh.shape["model"]
        rec["collectives_raw"] = hlo.collective_bytes(text, ms)
        rec["async_overlap"] = hlo.async_overlap_stats(text)
        rec["hlo_bytes"] = len(text)
        if save_hlo:
            import gzip
            with gzip.open(save_hlo, "wt") as zf:
                zf.write(text)

        if scan_correct and G > 1:
            cost1 = cost2 = coll1 = coll2 = None
            for nl, tag in ((p, 1), (2 * p, 2)):
                # unrolled so cost_analysis counts every layer (scan bodies
                # are otherwise counted once); full-depth keeps the scan.
                # attn/CE chunking is disabled here: those scans would be
                # unrolled too (16 q-chunks x 32 vocab-chunks x accum -> HLO
                # explosion) and the dense forms have identical FLOPs/bytes
                # semantics (streaming CE adds ~one remat pass of the head
                # matmul, a documented small underestimate for large vocabs)
                cfg_s = cfg.with_(n_layers=nl, scan_unroll=True,
                                  attn_chunk=0, ce_chunk=-1)
                _, comp_s, _, _ = lower_compile(cfg_s, shape, mesh, step)
                cs = hlo.cost_summary(comp_s)
                cb = hlo.collective_bytes(comp_s.as_text(), ms)
                if tag == 1:
                    cost1, coll1 = cs, cb
                else:
                    cost2, coll2 = cs, cb
            rec["cost_depth_points"] = {"L1": cost1, "L2": cost2}
            rec["cost"] = {
                k: hlo.extrapolate(cost1[k], cost2[k], G) for k in cost1
            }
            rec["collectives"] = {
                k: hlo.extrapolate(coll1[k], coll2[k], G) for k in coll1
            }
        else:
            rec["cost"] = dict(rec["cost_raw"])
            rec["collectives"] = dict(rec["collectives_raw"])

    if verbose:
        c = rec["cost"]
        mem = rec["memory"]
        ov = rec["async_overlap"]
        print(
            f"[ok] {arch} x {shape_name} x {mesh_name} ({step}): "
            f"flops={c['flops']:.3e} bytes={c['bytes']:.3e} "
            f"coll={rec['collectives']['total']:.3e}B "
            f"argbytes={mem.get('argument_size_in_bytes', 0):.3e} "
            f"temp={mem.get('temp_size_in_bytes', 0):.3e} "
            f"(lower {rec['lower_s']}s compile {rec['compile_s']}s)"
        )
        if ov["pairs"]:
            print(f"     async collectives: {ov['pairs']} start/done pairs, "
                  f"{ov['overlapped_pairs']} overlapped, mean gap "
                  f"{ov['mean_gap']:.1f} ops, max {ov['max_gap']}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCH_IDS + [None])
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--step", default="auto")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--no-correct", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--save-hlo", action="store_true",
                    help="also write <tag>.hlo.txt.gz of the full lowering")
    ap.add_argument("--xla-overlap", action="store_true",
                    help="compile under the async-collective + latency-"
                         "hiding scheduler flags (launch.xla) so the "
                         "recorded async_overlap stats show what the "
                         "scheduler actually hid")
    args = ap.parse_args()

    if args.xla_overlap:
        # must land before the first device query initializes the backend;
        # the flags are GPU-only and XLA aborts on unknown CPU flags, so on
        # the forced-host-device matrix we skip them (async_overlap stats
        # are still parsed from whatever HLO the backend schedules)
        if any(os.environ.get(k, "").lower() in ("cpu",)
               for k in ("JAX_PLATFORMS", "JAX_PLATFORM_NAME")):
            print("xla-overlap: CPU backend — GPU scheduler flags skipped")
        else:
            from repro.launch.xla import enable_collective_overlap
            enable_collective_overlap()

    os.makedirs(args.out, exist_ok=True)
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.mesh == "both" else [args.mesh == "multipod"]

    n_ok = n_skip = n_fail = 0
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                kinds = (
                    step_kinds(SHAPES[shape_name]) if args.step == "auto"
                    else (args.step,)
                )
                for step in kinds:
                    tag = f"{arch}__{shape_name}__{'multipod' if mp else 'pod'}__{step}"
                    out_path = os.path.join(args.out, tag + ".json")
                    if os.path.exists(out_path) and not args.force:
                        with open(out_path) as f:
                            prev = json.load(f)
                        if "error" not in prev:
                            print(f"[resume] {tag}: already done")
                            n_ok += prev.get("applicable", False)
                            n_skip += not prev.get("applicable", False)
                            continue
                    try:
                        # the roofline table reads single-pod numbers only;
                        # multipod runs prove lower+compile (skip the extra
                        # depth-point lowerings there)
                        rec = run_one(
                            arch, shape_name, mp, step,
                            scan_correct=not args.no_correct and not mp,
                            save_hlo=(out_path[:-5] + ".hlo.txt.gz"
                                      if args.save_hlo else ""))
                        n_ok += rec.get("applicable", False)
                        n_skip += not rec.get("applicable", False)
                    except Exception as e:  # a failure here is a bug: report it
                        n_fail += 1
                        rec = {"arch": arch, "shape": shape_name,
                               "mesh": "multipod" if mp else "pod",
                               "step": step, "applicable": True,
                               "error": f"{type(e).__name__}: {e}"}
                        print(f"[FAIL] {tag}: {rec['error']}")
                    with open(os.path.join(args.out, tag + ".json"), "w") as f:
                        json.dump(rec, f, indent=1)
    print(f"\ndry-run complete: {n_ok} ok, {n_skip} skipped, {n_fail} FAILED")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
