"""The dryrun's XLA environment setup, import-time on purpose.

Owns exactly ONE XLA knob — the forced host device count — and COMPOSES it
with whatever XLA_FLAGS the user exported (latency-hiding / async-collective
flags would otherwise silently vanish).  This must run before any
jax-importing module: jax locks the device count on first backend init.
Kept free of jax imports itself (``repro.launch.xla`` is pure string/env
code) so tests can re-import it and ``launch.dryrun`` can import it first.
"""
import os

from repro.launch.xla import append_xla_flags

DEVICES = os.environ.get("REPRO_DRYRUN_DEVICES", "512")
append_xla_flags(
    [f"--xla_force_host_platform_device_count={DEVICES}"],
    drop_prefixes=("--xla_force_host_platform_device_count",))
