"""Production meshes (TPU v5e): one 256-chip pod, or 2 pods = 512 chips.

Defined as functions (never module-level constants) so importing this module
never touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 4, model: int = 2, pod: int = 0):
    """Small mesh for CI subprocess tests (needs >= data*model*max(pod,1) devices)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants used by the roofline analysis.
HW = {
    "peak_flops_bf16": 197e12,   # per chip
    "hbm_bw": 819e9,             # bytes/s per chip
    "ici_bw": 50e9,              # bytes/s per link
    "hbm_bytes": 16 * 2**30,     # capacity per chip
}
