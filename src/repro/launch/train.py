"""End-to-end distributed training driver (HO-SGD or any baseline).

Runs the real thing on whatever devices exist (CPU devices here; the same
code drives a TPU slice).  Example — train a ~100M model for 200 steps:

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-14b --reduce 100m \
        --steps 200 --tau 8 --batch 16 --seq 256
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.checkpoint import save as ckpt_save
from repro.configs import ARCH_IDS, get_config
from repro.configs.base import ModelConfig
from repro.core.distributed import make_distributed_ho_sgd
from repro.core.ho_sgd import (
    HOSGDConfig, adaptive_tau_decision, parse_tau_schedule,
)
from repro.data import shard_batches, token_batches
from repro.dist import CommLedger, get_compressor
from repro.dist.sharding import named, param_specs, n_workers
from repro.launch.mesh import make_test_mesh
from repro.metrics import CSVLogger, comm_report
from repro.models import transformer as T
from repro.opt.optimizers import sgd, const_schedule


def size_override(cfg: ModelConfig, preset: str) -> ModelConfig:
    """Depth/width presets so examples fit the local device."""
    if preset == "full":
        return cfg
    if preset == "100m":
        return cfg.with_(
            n_layers=max(cfg.pattern_period * 4, 8), d_model=768,
            n_heads=12, n_kv_heads=max(1, min(cfg.n_kv_heads, 4)),
            head_dim=64, d_ff=2048, dense_d_ff=min(cfg.dense_d_ff, 2048),
            vocab_size=min(cfg.vocab_size, 32768),
            n_experts=min(cfg.n_experts, 8), dt_rank=48,
            dtype="float32",
        )
    if preset == "smoke":
        return cfg.reduced()
    raise ValueError(preset)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b", choices=ARCH_IDS)
    ap.add_argument("--reduce", default="smoke", choices=["full", "100m", "smoke"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--tau", type=int, default=8)
    ap.add_argument("--tau-schedule", default=None,
                    help="adaptive period: 'const:K' or "
                         "'linear:start,end,horizon' (needs --tau >= 2; "
                         "default: fixed --tau)")
    ap.add_argument("--mu", type=float, default=1e-3)
    ap.add_argument("--lr", type=float, default=3e-2)
    ap.add_argument("--zo-lr", type=float, default=None)
    ap.add_argument("--batch", type=int, default=8, help="global batch")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--data-axis", type=int, default=0, help="0 = all devices")
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--log", default=None)
    ap.add_argument("--compress", default="none",
                    choices=["none", "qsgd", "signsgd", "topk"],
                    help="codec on the FO gradient all-reduce")
    ap.add_argument("--compress-mode", default="per_worker",
                    choices=["per_worker", "legacy"],
                    help="per_worker: each worker encodes its shard "
                         "gradient, the reducer decodes (wire = nbytes x m);"
                         " legacy: post-reduction decode(encode(mean))")
    ap.add_argument("--engine", default="fused",
                    choices=["tree", "fused", "pallas", "flat"],
                    help="DirectionEngine backend for the ZO direction "
                         "algebra (repro.core.engine); 'flat' packs the "
                         "tree into one buffer and fuses the ZO round for "
                         "plain SGD")
    ap.add_argument("--fo-buckets", type=int, default=1,
                    help="chunk the FO gradient all-reduce into this many "
                         "independently-reducible buckets (bit-identical "
                         "math, same ledger bytes; pairs with --xla-overlap "
                         "so the scheduler hides them behind compute)")
    ap.add_argument("--xla-overlap", action="store_true",
                    help="append the async-collective + latency-hiding "
                         "scheduler XLA flags (launch.xla, composed with "
                         "any user-set XLA_FLAGS, never replacing them)")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="write a wall-clock Perfetto trace: one span per "
                         "jitted FO/ZO step (ledger bytes attached) plus a "
                         "cumulative received-bytes counter")
    args = ap.parse_args(argv)

    if args.xla_overlap:
        # must land before the first device query initializes the backend
        from repro.launch.xla import enable_collective_overlap
        enable_collective_overlap()
    n_dev = jax.device_count()
    data_ax = args.data_axis or max(1, n_dev // args.model_axis)
    mesh = make_test_mesh(data=data_ax, model=args.model_axis)
    m = n_workers(mesh)

    cfg = size_override(get_config(args.arch), args.reduce)
    if cfg.frontend != "none":
        raise SystemExit("use examples/ drivers for frontend archs")
    print(f"arch={cfg.name} params={cfg.param_count():,} mesh={dict(mesh.shape)} "
          f"workers={m}")

    params = T.init_model(jax.random.key(args.seed), cfg)
    loss_fn = lambda p, b: T.loss_fn(cfg, p, b)
    leaf_dims = [int(x.size) for x in jax.tree.leaves(params)]
    d = sum(leaf_dims)
    zo_lr = args.zo_lr if args.zo_lr is not None else args.lr * 50.0 / d
    ho = HOSGDConfig(tau=args.tau, mu=args.mu, m=m, lr=args.lr, zo_lr=zo_lr,
                     seed=args.seed, engine=args.engine)
    opt = sgd(const_schedule(args.lr))
    codec = get_compressor(args.compress)
    fo, zo = make_distributed_ho_sgd(loss_fn, mesh, ho, opt, model_cfg=cfg,
                                     params_like=params, compressor=codec,
                                     compress_mode=args.compress_mode,
                                     fo_buckets=args.fo_buckets)

    # adaptive tau: the same decision logic the Method and the simulator use
    # (core.ho_sgd.adaptive_tau_decision); the fixed-tau default path stays
    # bit-identical to before (t % tau, step keyed on t itself)
    tau_sched = parse_tau_schedule(args.tau_schedule) if args.tau_schedule else None
    if tau_sched is not None and args.tau < 2:
        raise SystemExit("--tau-schedule needs --tau >= 2 (the ZO seed map)")

    with compat.set_mesh(mesh):
        params = jax.device_put(params, named(mesh, param_specs(cfg, params, mesh)))
        opt_state = opt.init(params)
        ledger = CommLedger()
        fo_j = ledger.wrap("fo", jax.jit(fo))
        zo_j = ledger.wrap("zo", jax.jit(zo))

        host = token_batches(cfg.vocab_size, args.batch, args.seq, seed=args.seed)
        since_fo = 0
        tracer = None
        if args.trace:
            from repro.obs import Tracer
            tracer = Tracer(clock="wall")
        with CSVLogger(args.log,
                       ["step", "order", "loss", "dt", "comm_bytes"]) as logger:
            t_prev = time.perf_counter()
            for t, batch in zip(range(args.steps), shard_batches(host, mesh)):
                if tau_sched is None:
                    is_fo, t_step = t % args.tau == 0, t
                else:
                    is_fo, t_step, since_fo = adaptive_tau_decision(
                        t, since_fo, tau_sched(t), args.tau)
                name = "fo" if is_fo else "zo"
                step = fo_j if is_fo else zo_j
                t0 = time.perf_counter()
                if tracer is not None:
                    with tracer.span("compute", "train", name=f"{name}/{t}") as sp:
                        params, opt_state, loss = step(jnp.int32(t_step),
                                                       params, opt_state, batch)
                        loss = float(loss)       # blocks: dispatch is async
                        sp.nbytes = ledger.bytes_per_step(name)
                    tracer.counter(tracer.now(), "train", "ledger_bytes",
                                   ledger.total_bytes())
                else:
                    params, opt_state, loss = step(jnp.int32(t_step), params,
                                                   opt_state, batch)
                    loss = float(loss)           # blocks: dispatch is async
                dt_step = time.perf_counter() - t0
                if t % 10 == 0 or t == args.steps - 1:
                    now = time.perf_counter()
                    print(f"step {t:5d} ({'FO' if is_fo else 'ZO'}) "
                          f"loss={loss:.4f} dt={now - t_prev:.2f}s")
                    t_prev = now
                logger.log(step=t, order=int(is_fo), loss=loss, dt=dt_step,
                           comm_bytes=ledger.bytes_per_step(name))
            if args.ckpt:
                if tracer is not None:
                    with tracer.span("checkpoint", "train", name="ckpt_save"):
                        path = ckpt_save(args.ckpt, args.steps,
                                         jax.device_get(params))
                else:
                    path = ckpt_save(args.ckpt, args.steps,
                                     jax.device_get(params))
                print("checkpoint:", path)
        if tracer is not None:
            from repro.obs import write_trace
            write_trace(args.trace, tracer, title=f"train:{cfg.name}")
            print(f"wrote trace {args.trace} ({len(tracer.spans)} spans)")
    # dense FO exchange moves gradients in the param dtype (fp32 accumulator
    # when grad_accum microbatches); ZO coefficients are always fp32
    grad_bytes = 4 if cfg.grad_accum > 1 else jnp.dtype(cfg.dtype).itemsize
    for line in comm_report(ledger, d=d, m=m, tau=args.tau, codec=codec,
                            leaf_dims=leaf_dims, grad_bytes=grad_bytes):
        print(line)
    print("done; final loss", float(loss))
    return float(loss)


if __name__ == "__main__":
    main()
