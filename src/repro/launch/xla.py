"""XLA_FLAGS composition — append, never clobber.

Every launcher that needs an XLA flag (the dryrun's forced host device
count, the async-collective overlap flags below) must COMPOSE with whatever
the user already exported: overwriting ``XLA_FLAGS`` silently drops
latency-hiding/async-collective flags set in the environment, which is
exactly the bug this module exists to prevent.  Flags must be in the
environment before the jax backend initializes (first device query), so
launchers call these helpers at the top of ``main()``.
"""
from __future__ import annotations

import os
from typing import Iterable, Sequence, Tuple

#: the async-collective / latency-hiding scheduler set (SNIPPETS §3 idiom):
#: lets XLA run each bucket of the chunked flat-gradient reduce
#: (``core.distributed.lower_fo_round`` with ``--fo-buckets``) on the async
#: collective stream, overlapped with the compute producing the next chunk —
#: the real-path mirror of the sim's ``Overlap`` pricing.
OVERLAP_FLAGS: Tuple[str, ...] = (
    "--xla_gpu_enable_async_collectives=true",
    "--xla_gpu_enable_latency_hiding_scheduler=true",
    "--xla_gpu_enable_highest_priority_async_stream=true",
)


def compose_xla_flags(new_flags: Sequence[str],
                      current: str = "",
                      drop_prefixes: Iterable[str] = ()) -> str:
    """Merge ``new_flags`` into the ``current`` XLA_FLAGS string.

    Existing flags are preserved in order; any existing flag starting with
    one of ``drop_prefixes`` is removed first (the caller owns that knob —
    e.g. the dryrun owns ``--xla_force_host_platform_device_count``); new
    flags already present verbatim are not duplicated.  Pure string
    function so it is directly testable without touching the environment.
    """
    kept = [f for f in current.split()
            if not any(f.startswith(p) for p in drop_prefixes)]
    return " ".join(kept + [f for f in new_flags if f not in kept])


def append_xla_flags(new_flags: Sequence[str],
                     drop_prefixes: Iterable[str] = ()) -> str:
    """Compose ``new_flags`` into ``os.environ['XLA_FLAGS']`` in place and
    return the resulting string."""
    merged = compose_xla_flags(new_flags, os.environ.get("XLA_FLAGS", ""),
                               drop_prefixes)
    os.environ["XLA_FLAGS"] = merged
    return merged


def enable_collective_overlap() -> str:
    """Turn on the async-collective + latency-hiding scheduler flags
    (``--xla-overlap`` in ``launch.train``), composing with — never
    replacing — whatever XLA_FLAGS the user exported."""
    return append_xla_flags(OVERLAP_FLAGS)
