"""Compiled-HLO analysis: collective-bytes parsing + cost extraction.

``cost_analysis()`` counts while-loop (lax.scan) bodies ONCE, so totals for
the layer-scanned models are corrected by linear extrapolation over depth:
lower the same config at L = p and L = 2p layers (p = pattern period);
per-layer cost = c(2p) - c(p); total = c(p) + (n_layers/p - 1) * per-layer.
The same correction applies to collective bytes parsed from the HLO text.
"""
from __future__ import annotations

import re
from typing import Dict, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"\b([a-z]\d*[a-z0-9]*)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*(\(?[a-z0-9\[\],{}: #*\"]*\)?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")
_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")


def _classify_axis(line: str, model_size: int) -> str:
    """Does this collective run over the model axis (inside one worker) or
    across workers (the traffic the paper optimizes)?

    Device ids are worker-major (id = worker*model_size + model): a group
    stays inside one worker iff its ids all fall in one model_size-aligned
    block.  For iota forms the discriminator is the *stride span* of the
    fastest-varying grouped axis: stride * extent <= model_size (and the
    block-aligned start) keeps it within the model axis.
    """
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1).split("}", 1)[0].lstrip("{")
        ids = [int(x) for x in first.split(",") if x.strip() != ""]
        if len(ids) <= 1:
            return "model"  # degenerate singleton groups
        block = ids[0] // model_size
        same_block = all(i // model_size == block for i in ids)
        return "model" if same_block else "worker"
    m = _IOTA_RE.search(line)
    if m:
        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        perm = ([int(x) for x in m.group(4).split(",")]
                if m.group(4) else list(range(len(dims))))
        # group elements vary over the trailing transposed axes covering s
        # device-ids; span = max stride*extent over those axes
        strides = {}
        acc = 1
        for ax in range(len(dims) - 1, -1, -1):
            strides[ax] = acc
            acc *= dims[ax]
        span = 1
        need = s
        for ax in reversed(perm):
            if need <= 1:
                break
            take = min(dims[ax], need)
            span = max(span, strides[ax] * take)
            need = (need + take - 1) // take
        return "model" if span <= model_size else "worker"
    return "unknown"


def collective_bytes(hlo_text: str, model_size: int = 16) -> Dict[str, float]:
    """Sum result-shape bytes of every collective op, by kind and by axis.

    Uses the op *result* size (for all-gather that's the gathered size — the
    standard per-device wire approximation); async ``-done`` ops are skipped
    to avoid double counting.
    """
    out = {k: 0.0 for k in _COLLECTIVES}
    out["axis_model"] = 0.0
    out["axis_worker"] = 0.0
    out["axis_unknown"] = 0.0
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        b = _shape_bytes(m.group(1))
        out[m.group(2)] += b
        out["axis_" + _classify_axis(line, model_size)] += b
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


_LHS_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=")
_START_RE = re.compile(
    r"=\s*[^=]*\b(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)-start\(")
_DONE_RE = re.compile(
    r"=\s*[^=]*\b(?:all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)-done\(\s*%?([\w.\-]+)")


def async_overlap_stats(hlo_text: str) -> Dict:
    """How much work the scheduler put between each async collective's
    ``-start`` and its matching ``-done``.

    Walks the HLO text counting instruction lines (`` = `` assignments);
    for every ``<kind>-start`` whose ``-done`` consumes it, the *gap* is the
    number of instructions scheduled strictly between the two — the direct
    HLO-level witness of compute/comm overlap (gap 0 = the collective is
    synchronous in effect, whatever its op names say).  Returns::

        {"pairs": N, "overlapped_pairs": M,          # M pairs with gap > 0
         "by_kind": {kind: count}, "mean_gap": g, "max_gap": G}
    """
    open_starts: Dict[str, Tuple[str, int]] = {}   # lhs name -> (kind, idx)
    gaps = []
    kinds: Dict[str, int] = {}
    idx = 0
    for line in hlo_text.splitlines():
        lhs = _LHS_RE.match(line)
        if not lhs:
            continue
        idx += 1
        m = _START_RE.search(line)
        if m:
            open_starts[lhs.group(1)] = (m.group(1), idx)
            continue
        m = _DONE_RE.search(line)
        if m and m.group(1) in open_starts:
            kind, start_idx = open_starts.pop(m.group(1))
            gaps.append(idx - start_idx - 1)
            kinds[kind] = kinds.get(kind, 0) + 1
    return {
        "pairs": len(gaps),
        "overlapped_pairs": sum(1 for g in gaps if g > 0),
        "by_kind": kinds,
        "mean_gap": (sum(gaps) / len(gaps)) if gaps else 0.0,
        "max_gap": max(gaps) if gaps else 0,
    }


def extrapolate(c1: float, c2: float, n_groups: int) -> float:
    """c(L=p), c(L=2p) -> c(full): c1 + (G-1)*(c2-c1) with G = n_layers/p."""
    per = c2 - c1
    return c1 + (n_groups - 1) * per


def cost_summary(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):   # jax 0.4.x: one dict per computation
        ca = ca[0] if ca else {}
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
    }


def memory_summary(compiled) -> Dict[str, float]:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes", "host_argument_size_in_bytes",
              "host_output_size_in_bytes", "host_temp_size_in_bytes",
              "peak_memory_in_bytes", "serialized_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = float(v)
    return out
