"""Serving engine: a thin driver over the continuous-batching scheduler.

``Engine.generate`` keeps the seed signature (offline: submit every prompt,
drain the scheduler, return full sequences) but now runs the slotted
continuous-batching path: per-request bucketed prefill, one jitted decode
program over the slot pool, EOS honored (``ServeConfig.eos_id``), and the
drain loop exits as soon as every request retires instead of always paying
``max_new`` steps.  ``Engine.submit``/``Engine.step`` expose the open-loop
surface that ``repro.sim.traffic`` replays under Poisson arrivals.

``serve_step`` (one token against a full-length cache) remains the
decode-shape dry-run target.
"""
from __future__ import annotations

from typing import List, Optional

import jax

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.serving.scheduler import (  # noqa: F401  (re-exported surface)
    Request,
    Scheduler,
    ServeConfig,
    StepReport,
    sample_key,
)


class Engine:
    def __init__(self, cfg: ModelConfig, params, serve_cfg: ServeConfig,
                 key: Optional[jax.Array] = None):
        self.cfg = cfg
        self.params = params
        self.sc = serve_cfg
        self.scheduler = Scheduler(cfg, params, serve_cfg, key=key)

    # --- open-loop surface (used by sim.traffic) ----------------------- #
    def submit(self, prompt: List[int], max_new: int,
               key_id: Optional[int] = None) -> int:
        return self.scheduler.submit(prompt, max_new, key_id=key_id)

    def step(self) -> StepReport:
        return self.scheduler.step()

    @property
    def has_work(self) -> bool:
        return self.scheduler.has_work

    def result(self, rid: int) -> List[int]:
        req = self.scheduler.requests[rid]
        return list(req.prompt) + list(req.out)

    # --- offline driver (the seed surface) ------------------------------ #
    def generate(self, prompts: List[List[int]], max_new: int,
                 key: Optional[jax.Array] = None) -> List[List[int]]:
        """Submit every prompt, drain, return prompt+generated per request.

        ``key_id`` is the position in ``prompts``, so repeated calls on one
        engine with the same ``key`` resample identically (the scheduler's
        global rid counter keeps advancing, the sampling keys don't).
        """
        self.scheduler.key = key
        rids = [self.scheduler.submit(list(p), max_new, key_id=i)
                for i, p in enumerate(prompts)]
        while self.scheduler.has_work:
            self.scheduler.step()
        return [self.result(rid) for rid in rids]


def serve_step(cfg: ModelConfig, params, token, pos, caches):
    """The decode-shape dry-run target: one new token, full-length KV cache."""
    return T.decode_step(cfg, params, token, pos, caches)
