"""Slotted KV cache: a fixed pool of ``max_seq``-length cache slots.

The pool is one device-resident cache tree (``T.init_caches`` over
``slots`` batch rows); a request owns exactly one slot from admission to
retirement.  ``alloc``/``evict`` manage the host-side free list, ``assign``
scatters a single-request prefill cache into its slot, and the decode batch
is simply the whole pool driven with a per-slot position vector (``-1`` for
free slots) — so admission and eviction never change the jitted decode
program's shapes.  ``gather`` pulls per-slot views back out for inspection
and tests.

Slots are the fixed-``max_seq`` special case of a paged cache (the seed
engine already padded every cache to ``max_seq``); a paged-block allocator
can later replace the slot axis behind the same alloc/assign/evict surface.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as T


@partial(jax.jit, donate_argnums=(0,))
def _scatter_slot(pool: Dict, prefill: Dict, slot) -> Dict:
    """Write a B=1 prefill cache tree into pool row ``slot``.

    Cache leaves are layer-stacked ``(L, B, ...)``; the slot axis is axis 1.
    One executable per prefill shape (i.e. per bucket length) — the slot
    index stays dynamic so re-assignment never recompiles.
    """
    def upd(p, c):
        start = (0, slot) + (0,) * (p.ndim - 2)
        return jax.lax.dynamic_update_slice(p, c.astype(p.dtype), start)

    return jax.tree.map(upd, pool, prefill)


class SlotKVCache:
    """Fixed pool of ``slots`` KV-cache rows, each ``max_seq`` long."""

    def __init__(self, cfg: ModelConfig, slots: int, max_seq: int):
        assert slots >= 1 and max_seq >= 1
        self.cfg = cfg
        self.slots = slots
        self.max_seq = max_seq
        self.caches: Dict = T.init_caches(
            cfg, slots, max_seq, jnp.dtype(cfg.dtype))
        self._free: List[int] = list(range(slots - 1, -1, -1))  # pop() -> 0 first
        # host-side per-slot metadata: next write position (-1 = free slot)
        self.pos = np.full((slots,), -1, np.int64)
        self.owner = np.full((slots,), -1, np.int64)   # request id, -1 = free

    # ------------------------------------------------------------------ #
    @property
    def free_slots(self) -> int:
        return len(self._free)

    def live_slots(self) -> List[int]:
        return [s for s in range(self.slots) if self.owner[s] >= 0]

    def alloc(self, rid: int) -> Optional[int]:
        """Claim a free slot for request ``rid`` (None when the pool is full)."""
        if not self._free:
            return None
        slot = self._free.pop()
        assert self.owner[slot] < 0, f"slot {slot} double-allocated"
        self.owner[slot] = rid
        return slot

    def assign(self, slot: int, prefill_caches: Dict, prompt_len: int) -> None:
        """Install a request's prefill cache (B=1 tree, any bucket length
        <= max_seq) into ``slot``; decode continues at ``prompt_len``."""
        assert self.owner[slot] >= 0, f"assign to unallocated slot {slot}"
        assert 0 < prompt_len <= self.max_seq
        self.caches = _scatter_slot(
            self.caches, prefill_caches, jnp.int32(slot))
        self.pos[slot] = prompt_len

    def advance(self, slot: int) -> None:
        """One decode token written at ``pos[slot]``; bump the position."""
        assert self.owner[slot] >= 0
        self.pos[slot] += 1
        assert self.pos[slot] <= self.max_seq, "slot overran max_seq"

    def evict(self, slot: int) -> None:
        """Retire the slot's request and return the slot to the free pool.

        The cache rows are NOT zeroed: the next ``assign`` overwrites the
        prompt region and decode overwrites (then reads) strictly position
        by position, so stale rows are never attended.
        """
        assert self.owner[slot] >= 0, f"evict of free slot {slot}"
        self.owner[slot] = -1
        self.pos[slot] = -1
        self._free.append(slot)

    def gather(self, slots) -> Dict:
        """Per-slot cache views (packed along axis 1) for the given slots."""
        idx = jnp.asarray(list(slots), jnp.int32)
        return jax.tree.map(lambda c: jnp.take(c, idx, axis=1), self.caches)

    def pos_vector(self) -> np.ndarray:
        """(slots,) int32 positions for ``decode_step_slots``; -1 = inactive."""
        return self.pos.astype(np.int32)

    def check_invariants(self) -> None:
        free = set(self._free)
        assert len(free) == len(self._free), "free list holds duplicates"
        for s in range(self.slots):
            if s in free:
                assert self.owner[s] < 0 and self.pos[s] < 0
            else:
                assert self.owner[s] >= 0, f"slot {s} neither free nor owned"
                assert 0 < self.pos[s] <= self.max_seq
