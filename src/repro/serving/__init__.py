from repro.serving.engine import Engine, ServeConfig, serve_step  # noqa: F401
