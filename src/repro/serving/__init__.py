"""repro.serving — continuous-batching inference over a slotted KV cache.

  * ``cache``     — ``SlotKVCache``: fixed pool of max_seq-length slots
                    (alloc/assign/evict/gather; decode = the whole pool).
  * ``scheduler`` — FIFO admission, prefill-length buckets with cached
                    jitted executables, mid-decode admission, EOS/max_new
                    retirement, canonical per-(request, step) sampling keys.
  * ``engine``    — ``Engine``: offline ``generate`` (seed signature) plus
                    the open-loop ``submit``/``step`` surface that
                    ``repro.sim.traffic`` prices under Poisson arrivals.
"""
from repro.serving.cache import SlotKVCache  # noqa: F401
from repro.serving.engine import Engine, ServeConfig, serve_step  # noqa: F401
from repro.serving.scheduler import (  # noqa: F401
    Request,
    Scheduler,
    StepReport,
    default_buckets,
    sample_key,
)
