"""Continuous-batching scheduler over the slotted KV cache.

JetStream/``OfflineInference``-style structure: a FIFO admission queue,
prefill-length bucketing with one cached jitted prefill executable per
bucket, admission of new requests into free slots *mid-decode*, retirement
on EOS or ``max_new``, and ONE jitted ``decode_step_slots`` program over the
packed slot pool (per-slot positions, ``-1`` marking free slots) whose
shapes never change as requests come and go.

One ``step()`` = (admit as many queued requests as there are free slots,
each paying a bucketed prefill) + (one decode step over the live pool).
``StepReport`` records exactly what a cost model needs to price the step:
per-admission bucket lengths and the live-slot count — ``repro.sim.traffic``
turns those into simulated seconds via the training-side ``ComputeModel``.

Sampling keys: the canonical derivation is per (request, token index) —
``sample_key(base, key_id, step)`` with ``fold_in`` applied once per
component (the seed engine folded the step counter twice: ``generate``
folded ``key`` per step and ``_sample`` folded the same counter again).
Because the key never depends on the slot or on which step() admitted the
request, temperature>0 decoding is reproducible under continuous batching
regardless of admission order or pool packing.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.serving.cache import SlotKVCache


@dataclass
class ServeConfig:
    max_seq: int
    temperature: float = 0.0
    eos_id: int = -1          # disabled by default (synthetic vocabularies)
    slots: int = 8            # KV-cache pool size == max decode batch
    # prefill bucket lengths (sorted). None = auto: powers of two up to
    # max_seq for attention-only configs, exact-length (no padding) for
    # SSM/hybrid configs whose post-prompt state would integrate the pad tail.
    buckets: Optional[Tuple[int, ...]] = None


def default_buckets(max_seq: int) -> Tuple[int, ...]:
    bs: List[int] = []
    b = 8
    while b < max_seq:
        bs.append(b)
        b *= 2
    bs.append(max_seq)
    return tuple(bs)


def sample_key(base: jax.Array, key_id: int, step: int) -> jax.Array:
    """THE per-(request, token-index) sampling key. One fold per component."""
    return jax.random.fold_in(jax.random.fold_in(base, key_id), step)


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    key_id: int               # sampling-key identity (defaults to rid)
    out: List[int] = field(default_factory=list)   # generated tokens
    done: bool = False
    slot: int = -1            # live slot while decoding, -1 otherwise


@dataclass
class StepReport:
    """What one scheduler step did — the pricing interface for sim.traffic."""
    admitted: List[Tuple[int, int, int, int]]  # (rid, prompt_len, bucket_len,
                                               #  slot) — slot AT admission
                                               # (still valid if the request
                                               # retired inside the step)
    live: int                              # slots live for the decode step
    emitted: List[Tuple[int, int]]         # (rid, token) appended this step
    finished: List[Tuple[int, str]]        # (rid, phase) retired this step,
                                           # phase: "prefill" | "decode"


class Scheduler:
    def __init__(self, cfg: ModelConfig, params, sc: ServeConfig,
                 key: Optional[jax.Array] = None):
        assert not cfg.encoder_only, "encoder-only models don't decode"
        assert sc.slots >= 1
        self.cfg = cfg
        self.params = params
        self.sc = sc
        self.key = key
        self.pool = SlotKVCache(cfg, sc.slots, sc.max_seq)
        self.queue: Deque[Request] = deque()
        self.requests: Dict[int, Request] = {}
        self._next_rid = 0
        self._exact = cfg.has_ssm   # pad tokens would corrupt the SSM state
        self._buckets = (None if self._exact else
                         tuple(sorted(sc.buckets or default_buckets(sc.max_seq))))
        # one jax.jit instance per bucket length => one cached executable per
        # bucket, inspectable via .prefill_buckets()
        self._prefill_exec: Dict[int, Callable] = {}
        self._decode = jax.jit(
            lambda p, tok, pos, caches: T.decode_step_slots(cfg, p, tok, pos, caches))
        self._slot_tokens = np.zeros((sc.slots,), np.int32)

    # ------------------------------------------------------------------ #
    def submit(self, prompt: List[int], max_new: int,
               key_id: Optional[int] = None) -> int:
        assert len(prompt) >= 1 and max_new >= 1
        assert len(prompt) + max_new <= self.sc.max_seq, "max_seq too small"
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid, list(prompt), max_new,
                      rid if key_id is None else key_id)
        self.requests[rid] = req
        self.queue.append(req)
        return rid

    @property
    def has_work(self) -> bool:
        return bool(self.queue) or bool(self.pool.live_slots())

    def prefill_buckets(self) -> Tuple[int, ...]:
        """Bucket lengths with a compiled prefill executable so far."""
        return tuple(sorted(self._prefill_exec))

    def bucket_for(self, prompt_len: int) -> int:
        if self._exact:
            return prompt_len
        for b in self._buckets:
            if b >= prompt_len:
                return b
        raise AssertionError(f"prompt_len {prompt_len} > max_seq bucket")

    # ------------------------------------------------------------------ #
    def _prefill(self, bucket: int):
        fn = self._prefill_exec.get(bucket)
        if fn is None:
            cfg = self.cfg
            fn = jax.jit(
                lambda p, toks, last: T.prefill_at(cfg, p, {"tokens": toks}, last))
            self._prefill_exec[bucket] = fn
        return fn

    def _sample(self, logits: jax.Array, key_id: int, step: int) -> int:
        if self.sc.temperature <= 0 or self.key is None:
            return int(jnp.argmax(logits))
        k = sample_key(self.key, key_id, step)
        return int(jax.random.categorical(k, logits / self.sc.temperature))

    def _append(self, req: Request, tok: int, report: StepReport,
                phase: str) -> bool:
        """Record one generated token; returns True when the request retires."""
        req.out.append(tok)
        report.emitted.append((req.rid, tok))
        eos = self.sc.eos_id >= 0 and tok == self.sc.eos_id
        if eos or len(req.out) >= req.max_new:
            req.done = True
            report.finished.append((req.rid, phase))
            if req.slot >= 0:
                self.pool.evict(req.slot)
                req.slot = -1
            return True
        return False

    def step(self) -> StepReport:
        """Admit into free slots, then one decode step over the live pool."""
        report = StepReport([], 0, [], [])
        # --- admission: bucketed prefill straight into a free slot -------- #
        while self.queue and self.pool.free_slots:
            req = self.queue.popleft()
            L = len(req.prompt)
            bucket = self.bucket_for(L)
            toks = np.zeros((1, bucket), np.int32)
            toks[0, :L] = req.prompt
            logits, caches = self._prefill(bucket)(
                self.params, jnp.asarray(toks), jnp.asarray([L - 1], jnp.int32))
            tok = self._sample(logits[0], req.key_id, 0)
            slot = self.pool.alloc(req.rid)
            report.admitted.append((req.rid, L, bucket, slot))
            self.pool.assign(slot, caches, L)
            req.slot = slot
            if not self._append(req, tok, report, "prefill"):
                self._slot_tokens[slot] = tok
        # --- one decode step over the packed live pool -------------------- #
        live = self.pool.live_slots()
        report.live = len(live)
        if live:
            pos = self.pool.pos_vector()
            logits, self.pool.caches = self._decode(
                self.params, jnp.asarray(self._slot_tokens),
                jnp.asarray(pos), self.pool.caches)
            if self.sc.temperature <= 0 or self.key is None:
                toks = np.asarray(jnp.argmax(logits, axis=-1))
            else:
                toks = None
            for slot in live:
                req = self.requests[int(self.pool.owner[slot])]
                self.pool.advance(slot)   # the decode wrote req's token at pos
                tok = (int(toks[slot]) if toks is not None else
                       self._sample(logits[slot], req.key_id, len(req.out)))
                if not self._append(req, tok, report, "decode"):
                    self._slot_tokens[slot] = tok
        return report
