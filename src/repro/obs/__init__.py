"""repro.obs — span tracing, Perfetto timelines, trace-derived attribution.

One observability vocabulary across all three layers (README §repro.obs):

  * ``trace``  — nestable ``Span``s on per-worker/link/slot lanes, kind
    taxonomy ``compute | comm.exposed | comm.overlapped | queue.contention
    | barrier | checkpoint | prefill | decode``, byte counters; clock modes
    ``sim`` (deterministic, caller-supplied times) and ``wall``.
  * ``export`` — Chrome/Perfetto ``trace_event`` JSON, deterministically
    serialized (same spec seed ⇒ byte-identical artifact) and
    round-trippable (``spans_from_events``).
  * ``report`` — per-kind/per-lane time + byte attribution with the
    exposed-comm / queue-wait headline fractions, computable from the
    exported JSON alone.

The spans are derived from the same events the pricing uses (the sim's
event loop, the traffic replay's clock, the CommLedger's bytes) — never a
second bookkeeping path.
"""
from repro.obs.export import (  # noqa: F401
    dumps,
    load_trace_events,
    spans_from_events,
    trace_events,
    validate_trace_events,
    write_trace,
)
from repro.obs.report import (  # noqa: F401
    attribution,
    attribution_from_file,
    format_report,
)
from repro.obs.trace import (  # noqa: F401
    CLOCKS,
    KINDS,
    Span,
    Tracer,
    slot_lane,
    worker_lane,
)
