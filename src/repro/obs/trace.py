"""Span tracing: the one observability vocabulary for train / sim / serve.

A ``Span`` is a half-open interval ``[t0, t1]`` on a *lane* (one lane per
simulated worker, pod link or serving slot) with a ``kind`` drawn from the
fixed taxonomy below, an optional byte payload (``nbytes`` — always
ledger-measured, never re-derived) and an optional parent for nesting.

Two clock modes (``Tracer(clock=...)``):

* ``"sim"`` — deterministic simulated time: every span's ``t0``/``t1`` is
  supplied by the caller (the discrete-event loop, the traffic replay).
  Nothing here reads a wall clock, so same spec seed ⇒ identical spans ⇒
  byte-identical Perfetto export (``repro.obs.export``).
* ``"wall"`` — host wall clock: ``Tracer.span`` is a context manager that
  stamps ``perf_counter`` deltas against the tracer's epoch and nests via
  an explicit span stack (the real-path ``launch.train --trace`` mode).

The tracer is bookkeeping-free by design: consumers derive timelines
(``export``) and attribution (``report``) from the SAME spans — there is
never a second accounting path that could drift from what the pricing or
the ledger recorded.
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Iterator, List, Optional, Tuple

#: the span taxonomy — every span's ``kind`` is one of these
KINDS = (
    "compute",          # local FLOPs (oracle calls, prefill/decode math)
    "comm.exposed",     # collective time on the critical path
    "comm.overlapped",  # collective time hidden behind compute (buckets)
    "queue.contention", # waiting on a shared link / admission queue
    "barrier",          # waiting on slower participants (+ round markers)
    "checkpoint",       # save/restore round-trips, failure recovery
    "prefill",          # serving: admission prefill on a slot
    "decode",           # serving: decode occupancy of a slot
)

CLOCKS = ("sim", "wall")


def worker_lane(worker: int) -> str:
    """Canonical lane name for a simulated worker (-1 = cluster-wide)."""
    return f"worker/{worker}" if worker >= 0 else "cluster"


def slot_lane(slot: int) -> str:
    """Canonical lane name for a serving slot (-1 = retired at prefill)."""
    return f"slot/{slot}" if slot >= 0 else "slot/prefill-only"


@dataclass
class Span:
    """One traced interval.  ``src_kind`` carries the legacy event-tuple
    kind for spans that ARE committed events of the sim's event loop — the
    ``(time, kind, worker)`` determinism trace is derived from exactly
    those spans (``src_kind is None`` marks annotation-only spans that add
    timeline detail without entering the tuple view)."""

    kind: str
    lane: str
    t0: float
    t1: float
    name: str = ""
    nbytes: int = 0
    worker: int = -1
    src_kind: Optional[str] = None
    parent: int = -1

    def __post_init__(self):
        assert self.kind in KINDS, \
            f"unknown span kind {self.kind!r}; have {KINDS}"
        assert self.t1 >= self.t0 - 1e-12, \
            f"span ends before it starts: [{self.t0}, {self.t1}]"

    @property
    def duration(self) -> float:
        return self.t1 - self.t0


#: a counter sample: (t, lane, name, value) — e.g. cumulative ledger bytes
CounterSample = Tuple[float, str, str, float]


class Tracer:
    """Collects spans and counter samples under one clock mode."""

    def __init__(self, clock: str = "sim"):
        assert clock in CLOCKS, f"unknown clock {clock!r}; have {CLOCKS}"
        self.clock = clock
        self.spans: List[Span] = []
        self.counters: List[CounterSample] = []
        self._stack: List[int] = []
        self._epoch = time.perf_counter() if clock == "wall" else 0.0

    # ------------------------------------------------------------------ #
    def now(self) -> float:
        """Wall-clock seconds since the tracer's epoch (wall mode only)."""
        assert self.clock == "wall", "sim-mode time is supplied by callers"
        return time.perf_counter() - self._epoch

    def add(self, kind: str, lane: str, t0: float, t1: float, *,
            name: str = "", nbytes: int = 0, worker: int = -1,
            src_kind: Optional[str] = None,
            parent: Optional[int] = None) -> int:
        """Record a completed span (sim mode's only entry point); returns
        its index.  ``parent=None`` nests under the innermost open wall
        span, if any."""
        if parent is None:
            parent = self._stack[-1] if self._stack else -1
        self.spans.append(Span(kind, lane, float(t0), float(t1), name=name,
                               nbytes=int(nbytes), worker=worker,
                               src_kind=src_kind, parent=parent))
        return len(self.spans) - 1

    @contextmanager
    def span(self, kind: str, lane: str, *, name: str = "",
             nbytes: int = 0) -> Iterator[Span]:
        """Wall-clock span context manager: stamps ``now()`` on entry and
        exit, nests under the enclosing ``span``.  The yielded ``Span`` is
        live — mutate ``nbytes``/``name`` inside the block (e.g. once the
        CommLedger has booked the step)."""
        assert self.clock == "wall", "use add() with explicit times in sim mode"
        idx = self.add(kind, lane, self.now(), self.now(), name=name,
                       nbytes=nbytes)
        self._stack.append(idx)
        try:
            yield self.spans[idx]
        finally:
            self._stack.pop()
            self.spans[idx].t1 = self.now()

    def counter(self, t: float, lane: str, name: str, value: float) -> None:
        self.counters.append((float(t), lane, name, float(value)))

    # ------------------------------------------------------------------ #
    def lanes(self) -> List[str]:
        """Lane names in deterministic first-appearance order."""
        seen: List[str] = []
        for s in self.spans:
            if s.lane not in seen:
                seen.append(s.lane)
        for _, lane, _, _ in self.counters:
            if lane not in seen:
                seen.append(lane)
        return seen

    def extend(self, spans: List[Span],
               counters: Optional[List[CounterSample]] = None) -> None:
        """Adopt pre-built spans (e.g. ``SimResult.spans``) wholesale."""
        self.spans.extend(spans)
        if counters:
            self.counters.extend(counters)
