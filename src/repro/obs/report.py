"""Trace-derived cost attribution: where a run's time actually went.

``attribution`` folds a span list into per-kind seconds, per-kind bytes and
per-lane breakdowns, plus the headline fractions the frontier benchmarks
assert on:

* ``exposed_comm_fraction`` — comm.exposed seconds / makespan: the share of
  the run's critical path spent on collectives that compute could not hide
  (``benchmarks/sim_frontier.py --trace-report`` pins HO-SGD ≤ 0.05 vs
  sync-SGD ≥ 0.2 on the overlap cluster, cross-checked against the
  ``costs.exposed_comm_time`` closed forms within 1e-9);
* ``queue_wait_fraction`` — shared-link / admission queueing per makespan;
* ``bytes_total`` — ledger bytes carried on spans, never re-derived.

Everything here runs equally on live ``Span`` objects or on spans
reconstructed from an exported Perfetto JSON (``export.spans_from_events``)
— the attribution is a pure function of the artifact, so a report can be
regenerated long after the run.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

from repro.obs.export import load_trace_events, spans_from_events
from repro.obs.trace import KINDS, Span


def attribution(spans: Sequence[Span]) -> Dict:
    """Fold spans into per-kind / per-lane time + byte attribution."""
    kind_s = {k: 0.0 for k in KINDS}
    kind_bytes = {k: 0 for k in KINDS}
    lane_s: Dict[str, Dict[str, float]] = {}
    t_min, t_max = float("inf"), 0.0
    for s in spans:
        kind_s[s.kind] += s.duration
        kind_bytes[s.kind] += s.nbytes
        per = lane_s.setdefault(s.lane, {})
        per[s.kind] = per.get(s.kind, 0.0) + s.duration
        t_min = min(t_min, s.t0)
        t_max = max(t_max, s.t1)
    makespan = (t_max - t_min) if spans else 0.0
    span = makespan if makespan > 0 else 1.0
    return {
        "n_spans": len(spans),
        "makespan_s": makespan,
        "kind_seconds": kind_s,
        "kind_bytes": kind_bytes,
        "lane_seconds": lane_s,
        "bytes_total": sum(kind_bytes.values()),
        "exposed_comm_fraction": kind_s["comm.exposed"] / span,
        "overlapped_comm_fraction": kind_s["comm.overlapped"] / span,
        "queue_wait_fraction": kind_s["queue.contention"] / span,
        "barrier_fraction": kind_s["barrier"] / span,
    }


def attribution_from_file(path: str) -> Dict:
    """Attribution computed purely from an exported trace JSON."""
    return attribution(spans_from_events(load_trace_events(path)))


def format_report(att: Dict, *, title: str = "trace") -> List[str]:
    """Human-readable attribution lines (the CLI/benchmark print format)."""
    lines = [f"# {title}: {att['n_spans']} spans over "
             f"{att['makespan_s']:.6g}s, {att['bytes_total']} bytes"]
    for k in KINDS:
        s = att["kind_seconds"][k]
        if s <= 0.0 and att["kind_bytes"][k] <= 0:
            continue
        frac = s / att["makespan_s"] if att["makespan_s"] > 0 else 0.0
        lines.append(f"{title}/{k},{s:.6g}s,frac={frac:.4f},"
                     f"bytes={att['kind_bytes'][k]}")
    lines.append(
        f"{title}/headline,exposed_comm_fraction="
        f"{att['exposed_comm_fraction']:.4f},queue_wait_fraction="
        f"{att['queue_wait_fraction']:.4f}")
    return lines
