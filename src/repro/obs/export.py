"""Chrome/Perfetto ``trace_event`` export of traced spans.

Emits the JSON-object form (``{"traceEvents": [...]}``) that Perfetto and
``chrome://tracing`` load directly:

* one *thread* per lane (``tid`` = the lane's first-appearance index, with a
  ``thread_name`` metadata event naming it), all under one process whose
  ``process_name`` is the trace's title — so the timeline shows one row per
  worker / pod link / serving slot;
* one complete event (``ph: "X"``) per span — ``ts``/``dur`` in
  microseconds, ``cat`` = the span kind, ``args`` carrying the byte payload
  and the source event kind;
* one counter event (``ph: "C"``) per counter sample (ledger bytes etc.).

Serialization is deterministic: events are emitted in span order with
sorted keys and fixed separators, so the determinism contract extends to
the artifact itself — same spec seed ⇒ byte-identical trace JSON (pinned
in ``tests/test_obs.py``).
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Sequence

from repro.obs.trace import CounterSample, Span, Tracer

_S_TO_US = 1e6


def trace_events(spans: Sequence[Span],
                 counters: Sequence[CounterSample] = (),
                 *, title: str = "repro") -> List[Dict]:
    """Flatten spans + counters into a ``trace_event`` list."""
    lanes: List[str] = []
    for s in spans:
        if s.lane not in lanes:
            lanes.append(s.lane)
    for _, lane, _, _ in counters:
        if lane not in lanes:
            lanes.append(lane)
    tid = {lane: i for i, lane in enumerate(lanes)}

    events: List[Dict] = [{
        "ph": "M", "pid": 1, "tid": 0, "name": "process_name",
        "args": {"name": title},
    }]
    for lane in lanes:
        events.append({
            "ph": "M", "pid": 1, "tid": tid[lane], "name": "thread_name",
            "args": {"name": lane},
        })
    for s in spans:
        args: Dict = {"nbytes": s.nbytes}
        if s.src_kind is not None:
            args["src"] = s.src_kind
        if s.worker >= 0:
            args["worker"] = s.worker
        events.append({
            "ph": "X", "pid": 1, "tid": tid[s.lane],
            "name": s.name or s.kind, "cat": s.kind,
            "ts": s.t0 * _S_TO_US, "dur": (s.t1 - s.t0) * _S_TO_US,
            "args": args,
        })
    for t, lane, name, value in counters:
        events.append({
            "ph": "C", "pid": 1, "tid": tid[lane], "name": name,
            "ts": t * _S_TO_US, "args": {name: value},
        })
    return events


def validate_trace_events(events: Sequence[Dict]) -> None:
    """Schema check: every event carries what Perfetto's trace_event
    importer requires (raises AssertionError on violation)."""
    assert events, "empty trace"
    for ev in events:
        assert ev.get("ph") in ("X", "C", "M"), f"bad phase in {ev}"
        assert isinstance(ev.get("pid"), int) and isinstance(ev.get("tid"), int)
        assert "name" in ev
        if ev["ph"] == "X":
            assert isinstance(ev["ts"], float) and isinstance(ev["dur"], float)
            assert ev["dur"] >= -1e-6, f"negative duration in {ev}"
        elif ev["ph"] == "C":
            assert isinstance(ev["ts"], float) and ev["args"]


def dumps(spans: Sequence[Span], counters: Sequence[CounterSample] = (),
          *, title: str = "repro") -> str:
    """Deterministic serialization (sorted keys, fixed separators)."""
    events = trace_events(spans, counters, title=title)
    validate_trace_events(events)
    return json.dumps({"displayTimeUnit": "ms", "traceEvents": events},
                      sort_keys=True, separators=(",", ":"))


def write_trace(path: str, source, counters: Optional[Sequence[CounterSample]] = None,
                *, title: str = "repro") -> str:
    """Write a Perfetto-loadable trace JSON; ``source`` is a ``Tracer`` or a
    span list.  Returns ``path``."""
    if isinstance(source, Tracer):
        spans, ctrs = source.spans, source.counters
    else:
        spans, ctrs = list(source), list(counters or [])
    if counters is not None:
        ctrs = list(counters)
    with open(path, "w") as f:
        f.write(dumps(spans, ctrs, title=title))
    return path


def load_trace_events(path: str) -> List[Dict]:
    with open(path) as f:
        doc = json.load(f)
    events = doc["traceEvents"] if isinstance(doc, dict) else doc
    validate_trace_events(events)
    return events


def spans_from_events(events: Sequence[Dict]) -> List[Span]:
    """Reconstruct spans from exported trace events — the round-trip that
    lets ``report.attribution`` run on the artifact alone."""
    lane_of: Dict[int, str] = {}
    for ev in events:
        if ev["ph"] == "M" and ev["name"] == "thread_name":
            lane_of[ev["tid"]] = ev["args"]["name"]
    spans = []
    for ev in events:
        if ev["ph"] != "X":
            continue
        args = ev.get("args", {})
        t0 = ev["ts"] / _S_TO_US
        spans.append(Span(
            kind=ev["cat"], lane=lane_of[ev["tid"]],
            t0=t0, t1=t0 + ev["dur"] / _S_TO_US,
            name=ev["name"], nbytes=int(args.get("nbytes", 0)),
            worker=int(args.get("worker", -1)),
            src_kind=args.get("src")))
    return spans
