from repro.metrics.logging import (  # noqa: F401
    CSVLogger,
    MeterRegistry,
    comm_report,
)
