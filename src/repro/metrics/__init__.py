from repro.metrics.logging import CSVLogger, MeterRegistry  # noqa: F401
