"""Training metrics: CSV logging + communication/compute meters.

The meters track the *analytic* per-method cost model (Method.comm_scalars
etc.) alongside measured losses, so the Table-1 benchmark can print measured
convergence against modeled communication/computation load.
"""
from __future__ import annotations

import csv
import os
import time
from typing import Dict, Optional


class CSVLogger:
    def __init__(self, path: Optional[str], fields):
        self.path = path
        self.fields = list(fields)
        self._writer = None
        self._fh = None
        if path:
            os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
            self._fh = open(path, "w", newline="")
            self._writer = csv.DictWriter(self._fh, fieldnames=self.fields)
            self._writer.writeheader()

    def log(self, **row):
        unknown = set(row) - set(self.fields)
        if unknown:
            raise ValueError(
                f"CSVLogger: unknown keys {sorted(unknown)}; declared fields "
                f"are {self.fields}")
        if self._writer:
            self._writer.writerow({k: row.get(k, "") for k in self.fields})
            self._fh.flush()

    def close(self):
        if self._fh:
            self._fh.close()
            self._fh = self._writer = None

    # context-manager support: the training drivers hold the file open for
    # the whole run, so an exception mid-loop must still release the handle
    def __enter__(self) -> "CSVLogger":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.close()
        return False


class MeterRegistry:
    """Accumulates per-method cost counters over a run."""

    def __init__(self, d: int):
        self.d = d
        self.scalars_sent = 0.0      # per worker
        self.fevals = 0.0
        self.gevals = 0.0
        self.t0 = time.perf_counter()

    def tick(self, method, iters: int = 1):
        self.scalars_sent += method.comm_scalars(self.d) * iters
        self.fevals += method.fevals(self.d) * iters
        self.gevals += method.gevals(self.d) * iters

    def summary(self) -> Dict[str, float]:
        return {
            "scalars_sent_per_worker": self.scalars_sent,
            "fevals_per_worker": self.fevals,
            "gevals_per_worker": self.gevals,
            "wall_s": time.perf_counter() - self.t0,
        }


def comm_report(ledger, d: int, m: int, tau: int,
                scalar_bytes: int = 4, codec=None, leaf_dims=None,
                grad_bytes: int = None) -> list:
    """Measured-vs-analytic communication lines (paper Table 1, in bytes).

    ``ledger`` is a repro.dist.CommLedger whose FO/ZO step programs were
    wrapped under the names ``"fo"``/``"zo"``.  Analytic model per worker per
    iteration (ledger convention: bytes *received*): FO moves the d-dim
    gradient (d scalars), ZO gathers one scalar from each of the m workers
    (m scalars); amortized over a period of tau that is (d + (tau-1)*m)/tau
    scalars — Table 1's (tau-1+d)/tau up to the m-vs-1 receive convention.
    Pass the active ``codec`` (repro.dist.Compressor) so the analytic FO
    column uses its wire model instead of the dense scalar_bytes*d — and
    ``leaf_dims`` (per-leaf parameter counts) with it, because the codec is
    applied per leaf (one norm/scale header each), not to one flat vector.
    The amortized line uses the ledger's *actual* FO/ZO step counts, so the
    columns agree for any --steps, not just whole tau-periods.  ``grad_bytes``
    is the dense FO exchange's per-scalar width — the gradient dtype's
    itemsize (2 for bf16 archs) — while the ZO coefficients are always fp32,
    so they keep ``scalar_bytes``.
    """
    fo_b = ledger.bytes_per_step("fo")
    zo_b = ledger.bytes_per_step("zo")
    n_fo = ledger.steps.get("fo", 0)
    n_zo = ledger.steps.get("zo", 0)
    iters = n_fo + n_zo
    if codec is None:
        fo_analytic = (grad_bytes or scalar_bytes) * d
    else:
        fo_analytic = sum(codec.nbytes(n) for n in (leaf_dims or [d]))
    tag = f"[{codec.name}]" if codec is not None else ""
    lines = [
        "# communication (bytes/worker): measured (CommLedger) vs analytic",
        f"comm/fo_bytes_per_step{tag},measured={fo_b},analytic={fo_analytic}",
        f"comm/zo_bytes_per_step,measured={zo_b},analytic={scalar_bytes * m}",
    ]
    if iters:
        measured = ledger.total_bytes() / iters
        analytic = (n_fo * fo_analytic + n_zo * scalar_bytes * m) / iters
        lines.append(
            f"comm/amortized_bytes_per_iter,measured={measured:.1f},"
            f"analytic={analytic:.1f},steps={iters}")
    return lines
