"""Training metrics: CSV logging + communication/compute meters.

The meters track the *analytic* per-method cost model (Method.comm_scalars
etc.) alongside measured losses, so the Table-1 benchmark can print measured
convergence against modeled communication/computation load.
"""
from __future__ import annotations

import csv
import os
import time
from typing import Dict, Optional


class CSVLogger:
    def __init__(self, path: Optional[str], fields):
        self.path = path
        self.fields = list(fields)
        self._writer = None
        self._fh = None
        if path:
            os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
            self._fh = open(path, "w", newline="")
            self._writer = csv.DictWriter(self._fh, fieldnames=self.fields)
            self._writer.writeheader()

    def log(self, **row):
        if self._writer:
            self._writer.writerow({k: row.get(k, "") for k in self.fields})
            self._fh.flush()

    def close(self):
        if self._fh:
            self._fh.close()


class MeterRegistry:
    """Accumulates per-method cost counters over a run."""

    def __init__(self, d: int):
        self.d = d
        self.scalars_sent = 0.0      # per worker
        self.fevals = 0.0
        self.gevals = 0.0
        self.t0 = time.perf_counter()

    def tick(self, method, iters: int = 1):
        self.scalars_sent += method.comm_scalars(self.d) * iters
        self.fevals += method.fevals(self.d) * iters
        self.gevals += method.gevals(self.d) * iters

    def summary(self) -> Dict[str, float]:
        return {
            "scalars_sent_per_worker": self.scalars_sent,
            "fevals_per_worker": self.fevals,
            "gevals_per_worker": self.gevals,
            "wall_s": time.perf_counter() - self.t0,
        }
