"""Device pipeline: shard host batches onto the mesh with double-buffering."""
from __future__ import annotations

import itertools
from collections import deque
from typing import Any, Iterator

import jax
from jax.sharding import Mesh, NamedSharding

from repro.dist.sharding import batch_specs


def shard_batches(
    host_batches: Iterator[Any],
    mesh: Mesh,
    prefetch: int = 2,
) -> Iterator[Any]:
    """Async device_put of host batches with a small prefetch queue."""
    spec_cache = {}

    def put(batch):
        key = tuple(sorted(jax.tree.map(lambda x: (x.shape, str(x.dtype)), batch).items())) \
            if isinstance(batch, dict) else None
        if key not in spec_cache:
            spec_cache[key] = jax.tree.map(
                lambda s: NamedSharding(mesh, s), batch_specs(mesh, batch),
                is_leaf=lambda x: hasattr(x, "index"))
        return jax.device_put(batch, spec_cache[key])

    queue: deque = deque()
    it = iter(host_batches)
    for b in itertools.islice(it, prefetch):
        queue.append(put(b))
    while queue:
        out = queue.popleft()
        try:
            queue.append(put(next(it)))
        except StopIteration:
            pass
        yield out


def take(it: Iterator[Any], n: int):
    return itertools.islice(it, n)
