from repro.data.synthetic import (  # noqa: F401
    DATASET_SPECS,
    Dataset,
    batches,
    make_classification,
    make_digits,
    token_batches,
)
from repro.data.libsvm import parse_libsvm, try_load  # noqa: F401
from repro.data.pipeline import shard_batches, take  # noqa: F401
