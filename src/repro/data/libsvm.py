"""libsvm-format multiclass dataset loader (for the paper's §5.2 datasets).

The container is offline; when the real SENSORLESS/ACOUSTIC/COVTYPE/SEISMIC
files are placed under ``data_dir`` this loader uses them, otherwise callers
fall back to ``repro.data.synthetic.make_classification``.
"""
from __future__ import annotations

import os
from typing import Optional, Tuple

import numpy as np


def parse_libsvm(path: str, n_features: Optional[int] = None) -> Tuple[np.ndarray, np.ndarray]:
    xs, ys = [], []
    max_f = n_features or 0
    rows = []
    with open(path) as f:
        for line in f:
            parts = line.split()
            if not parts:
                continue
            ys.append(float(parts[0]))
            feats = {}
            for tok in parts[1:]:
                i, v = tok.split(":")
                feats[int(i)] = float(v)
                max_f = max(max_f, int(i))
            rows.append(feats)
    x = np.zeros((len(rows), max_f), np.float32)
    for r, feats in enumerate(rows):
        for i, v in feats.items():
            x[r, i - 1] = v  # libsvm is 1-indexed
    y = np.asarray(ys)
    # labels may be 1-indexed or arbitrary ints; remap to 0..C-1
    uniq = np.unique(y)
    remap = {v: i for i, v in enumerate(uniq)}
    y = np.asarray([remap[v] for v in y], np.int32)
    return x, y


def try_load(name: str, data_dir: str = "data"):
    """Returns a Dataset if real files exist, else None."""
    from repro.data.synthetic import Dataset

    train = os.path.join(data_dir, f"{name}.train")
    test = os.path.join(data_dir, f"{name}.test")
    if not (os.path.exists(train) and os.path.exists(test)):
        return None
    xtr, ytr = parse_libsvm(train)
    xte, yte = parse_libsvm(test, n_features=xtr.shape[1])
    mu, sd = xtr.mean(0), xtr.std(0) + 1e-6
    return Dataset(name, (xtr - mu) / sd, ytr, (xte - mu) / sd, yte)
