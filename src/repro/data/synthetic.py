"""Deterministic synthetic data: LM token streams, classification datasets,
and the paper's experiment surrogates (digit images for the attack task).

The container has no network; the four §5.2 datasets (SENSORLESS, ACOUSTIC,
COVTYPE, SEISMIC) are emulated as seeded Gaussian-mixture problems with the
published feature/class counts — the optimizer comparison (the paper's
claim) is about convergence behaviour, not dataset identity.  Real libsvm
files are supported via ``repro.data.libsvm`` when present on disk.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass
from typing import Dict, Iterator

import numpy as np

DATASET_SPECS = {
    # name: (n_features, n_classes) — per Table 4 of the paper
    "sensorless": (48, 11),
    "acoustic": (50, 3),
    "covtype": (54, 7),
    "seismic": (50, 3),
}


@dataclass
class Dataset:
    name: str
    x_train: np.ndarray
    y_train: np.ndarray
    x_test: np.ndarray
    y_test: np.ndarray

    @property
    def n_features(self) -> int:
        return self.x_train.shape[1]

    @property
    def n_classes(self) -> int:
        return int(self.y_train.max()) + 1


def make_classification(name: str, n_train: int = 8192, n_test: int = 2048,
                        seed: int = 0, class_sep: float = 1.6) -> Dataset:
    if name not in DATASET_SPECS:
        raise KeyError(f"unknown dataset {name!r}; have {list(DATASET_SPECS)}")
    d, c = DATASET_SPECS[name]
    # stable hash: python's str hash is randomized per process, which would
    # make the "seeded" dataset differ between runs (the sim benchmarks
    # compare time-to-loss across processes)
    rng = np.random.default_rng(seed + zlib.crc32(name.encode()) % (1 << 16))
    centers = rng.normal(size=(c, d)) * class_sep
    # anisotropic within-class covariance for a non-trivial decision surface
    mix = rng.normal(size=(c, d, d)) * 0.15 + np.eye(d)

    def sample(n):
        y = rng.integers(0, c, size=n)
        eps = rng.normal(size=(n, d))
        x = centers[y] + np.einsum("nd,ndk->nk", eps, mix[y])
        return x.astype(np.float32), y.astype(np.int32)

    xtr, ytr = sample(n_train)
    xte, yte = sample(n_test)
    mu, sd = xtr.mean(0), xtr.std(0) + 1e-6
    return Dataset(name, (xtr - mu) / sd, ytr, (xte - mu) / sd, yte)


def batches(ds: Dataset, batch: int, seed: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    """Infinite iterator of i.i.d. sampled batches (with-replacement, as the
    paper's stochastic-oracle model assumes)."""
    rng = np.random.default_rng(seed)
    n = ds.x_train.shape[0]
    while True:
        idx = rng.integers(0, n, size=batch)
        yield {"x": ds.x_train[idx], "y": ds.y_train[idx]}


# --------------------------------------------------------------------------- #
# LM token stream
# --------------------------------------------------------------------------- #
def token_batches(vocab: int, batch: int, seq: int, seed: int = 0,
                  zipf_a: float = 1.3) -> Iterator[Dict[str, np.ndarray]]:
    """Zipf-distributed token batches with next-token labels (-1 on the last
    position, which has no target)."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = ranks ** (-zipf_a)
    probs /= probs.sum()
    while True:
        toks = rng.choice(vocab, size=(batch, seq), p=probs).astype(np.int32)
        labels = np.full((batch, seq), -1, np.int32)
        labels[:, :-1] = toks[:, 1:]
        yield {"tokens": toks, "labels": labels}


# --------------------------------------------------------------------------- #
# synthetic digits (the §5.1 adversarial-attack surrogate for MNIST, d=900)
# --------------------------------------------------------------------------- #
def make_digits(n: int = 2048, side: int = 30, n_classes: int = 10,
                seed: int = 0):
    """30x30 'digit' images (d = 900, matching the paper's attack dimension):
    each class is a fixed smooth template + small pixel noise, in [-0.5, 0.5].
    """
    rng = np.random.default_rng(seed)
    yy, xx = np.mgrid[0:side, 0:side].astype(np.float64) / side
    templates = []
    for c in range(n_classes):
        ph = rng.uniform(0, 2 * np.pi, size=4)
        f = rng.uniform(1.5, 4.0, size=4)
        t = (
            np.sin(2 * np.pi * f[0] * xx + ph[0])
            + np.cos(2 * np.pi * f[1] * yy + ph[1])
            + np.sin(2 * np.pi * f[2] * (xx + yy) + ph[2])
            + np.cos(2 * np.pi * f[3] * (xx - yy) + ph[3])
        )
        templates.append(t / (np.abs(t).max() * 2.2))
    templates = np.stack(templates)
    y = rng.integers(0, n_classes, size=n).astype(np.int32)
    x = templates[y] + rng.normal(0, 0.02, size=(n, side, side))
    # keep pixels off the +-0.5 boundary: the attack's tanh re-param
    # (z = 0.5*tanh(atanh(2a)+x)) is exactly invertible only for |2a| < 1,
    # so saturated pixels would perturb images even at x = 0
    x = np.clip(x, -0.45, 0.45).astype(np.float32).reshape(n, side * side)
    return x, y
