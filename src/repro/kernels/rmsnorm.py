"""Fused RMSNorm Pallas kernel (rows tiled to VMEM, fp32 accumulation)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * (1.0 + s_ref[...].astype(jnp.float32))
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm_pallas(
    x: jax.Array,            # (R, D) — callers flatten leading dims
    scale: jax.Array,        # (D,)
    eps: float = 1e-6,
    block_rows: int = 128,
    interpret: bool = True,
) -> jax.Array:
    R, D = x.shape
    block_rows = min(block_rows, R)
    assert R % block_rows == 0, (R, block_rows)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        out_shape=jax.ShapeDtypeStruct((R, D), x.dtype),
        grid=(R // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        interpret=interpret,
    )(x, scale)
