"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.directions import gaussian_from_salt


def ref_rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def ref_attention(
    q: jax.Array,            # (BH, Sq, hd)
    k: jax.Array,            # (BH, Sk, hd)
    v: jax.Array,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
) -> jax.Array:
    Sq, hd = q.shape[1], q.shape[2]
    Sk = k.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / jnp.sqrt(jnp.float32(hd))
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    rel = jnp.arange(Sq)[:, None] - jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= rel >= 0
    if window is not None:
        mask &= rel < window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def ref_selective_scan(u, dt, Bmat, Cmat, A, D):
    """Sequential lax.scan oracle of the mamba recurrence."""
    uf = u.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    def step(h, xs):
        u_t, dt_t, B_t, C_t = xs                      # (B,di),(B,di),(B,n),(B,n)
        dA = jnp.exp(dt_t[..., None] * A)             # (B,di,n)
        dBu = (dt_t * u_t)[..., None] * B_t[:, None, :]
        h = dA * h + dBu
        y = jnp.sum(h * C_t[:, None, :], axis=-1) + D * u_t
        return h, y

    B, S, di = u.shape
    n = A.shape[1]
    h0 = jnp.zeros((B, di, n), jnp.float32)
    xs = (uf.swapaxes(0, 1), dtf.swapaxes(0, 1),
          Bmat.astype(jnp.float32).swapaxes(0, 1),
          Cmat.astype(jnp.float32).swapaxes(0, 1))
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.swapaxes(0, 1).astype(u.dtype)


def ref_zo_sumsq(n: int, salt, offset=0) -> jax.Array:
    g = gaussian_from_salt((n,), jnp.asarray(salt, jnp.uint32), offset)
    return jnp.sum(g * g)


def ref_zo_perturb(x: jax.Array, salt, scale, offset=0) -> jax.Array:
    g = gaussian_from_salt(x.shape, jnp.asarray(salt, jnp.uint32), offset)
    return (x.astype(jnp.float32) + jnp.float32(scale) * g).astype(x.dtype)


def ref_zo_reconstruct(n: int, salts, coeffs, offset=0,
                       acc_dtype=jnp.float32) -> jax.Array:
    """``acc_dtype`` rounds the accumulator after each worker, mirroring the
    kernel's (and the DirectionEngine accumulators') per-worker semantics."""
    adt = jnp.dtype(acc_dtype)
    acc = jnp.zeros((n,), jnp.float32)
    for w in range(salts.shape[0]):
        g = gaussian_from_salt((n,), jnp.asarray(salts[w], jnp.uint32), offset)
        acc = (acc + coeffs[w] * g).astype(adt).astype(jnp.float32)
    return acc


# --------------------------------------------------------------------------- #
# flat (packed multi-leaf) oracles.  These consume the same per-block
# metadata as the kernels — (salt, leaf-local counter start, valid lanes)
# per block — and mirror the kernels' blockwise evaluation order exactly,
# including the blockwise-sequential sumsq accumulation (which is why the
# fused sumsq is only ulp-close, not bitwise-equal, to a whole-leaf jnp
# reduction).
# --------------------------------------------------------------------------- #
def _ref_flat_gauss(salt, ctr, nvalid, block: int) -> jax.Array:
    g = gaussian_from_salt((block,), jnp.asarray(salt, jnp.uint32),
                           jnp.asarray(ctr, jnp.uint32))
    return jnp.where(jnp.arange(block) < nvalid, g, 0.0)


def ref_zo_perturb_sumsq(x, salts, ctrs, nvalid, mu, block: int):
    """Oracle of the fused perturb+sumsq: returns ``(x_perturbed, sumsq)``."""
    nb = int(salts.shape[0])
    ss = jnp.float32(0.0)
    gs = []
    for b in range(nb):
        g = _ref_flat_gauss(salts[b], ctrs[b], nvalid[b], block)
        ss = ss + jnp.sum(g * g)
        gs.append(g)
    scale = jnp.float32(mu) * jax.lax.rsqrt(ss + 1e-30)
    out = x.astype(jnp.float32) + scale * jnp.concatenate(gs)
    return out, ss


def ref_zo_reconstruct_update(p, mom, salts, ctrs, nvalid, bf16_mask, coeffs,
                              lr, momentum: float = 0.0, block: int = 4096,
                              acc_dtype=jnp.float32):
    """Oracle of the fused reconstruct + SGD(+momentum) commit.

    Returns ``(p', mom')`` with ``mom'`` None when ``mom`` is None,
    mirroring ``zo_reconstruct_update``: per-worker acc_dtype rounding,
    masked padding lanes, bf16 leaves rounded through bf16 on commit.
    """
    adt = jnp.dtype(acc_dtype)
    nb, m = salts.shape
    upd = []
    for b in range(int(nb)):
        acc = jnp.zeros((block,), jnp.float32)
        for w in range(int(m)):
            g = gaussian_from_salt((block,), jnp.asarray(salts[b, w], jnp.uint32),
                                   jnp.asarray(ctrs[b], jnp.uint32))
            acc = (acc + coeffs[w] * g).astype(adt).astype(jnp.float32)
        upd.append(jnp.where(jnp.arange(block) < nvalid[b], acc, 0.0))
    g_full = jnp.concatenate(upd)
    neg_lr = -jnp.float32(lr)
    if mom is not None:
        v_new = jnp.float32(momentum) * mom.astype(jnp.float32) + g_full
        p_new = p.astype(jnp.float32) + neg_lr * v_new
    else:
        v_new = None
        p_new = p.astype(jnp.float32) + neg_lr * g_full
    bf = jnp.repeat(jnp.asarray(bf16_mask) != 0, block)
    p_new = jnp.where(bf, p_new.astype(jnp.bfloat16).astype(jnp.float32), p_new)
    return p_new, v_new
