"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.directions import gaussian_from_salt


def ref_rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def ref_attention(
    q: jax.Array,            # (BH, Sq, hd)
    k: jax.Array,            # (BH, Sk, hd)
    v: jax.Array,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
) -> jax.Array:
    Sq, hd = q.shape[1], q.shape[2]
    Sk = k.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / jnp.sqrt(jnp.float32(hd))
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    rel = jnp.arange(Sq)[:, None] - jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= rel >= 0
    if window is not None:
        mask &= rel < window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def ref_selective_scan(u, dt, Bmat, Cmat, A, D):
    """Sequential lax.scan oracle of the mamba recurrence."""
    uf = u.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    def step(h, xs):
        u_t, dt_t, B_t, C_t = xs                      # (B,di),(B,di),(B,n),(B,n)
        dA = jnp.exp(dt_t[..., None] * A)             # (B,di,n)
        dBu = (dt_t * u_t)[..., None] * B_t[:, None, :]
        h = dA * h + dBu
        y = jnp.sum(h * C_t[:, None, :], axis=-1) + D * u_t
        return h, y

    B, S, di = u.shape
    n = A.shape[1]
    h0 = jnp.zeros((B, di, n), jnp.float32)
    xs = (uf.swapaxes(0, 1), dtf.swapaxes(0, 1),
          Bmat.astype(jnp.float32).swapaxes(0, 1),
          Cmat.astype(jnp.float32).swapaxes(0, 1))
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.swapaxes(0, 1).astype(u.dtype)


def ref_zo_sumsq(n: int, salt, offset=0) -> jax.Array:
    g = gaussian_from_salt((n,), jnp.asarray(salt, jnp.uint32), offset)
    return jnp.sum(g * g)


def ref_zo_perturb(x: jax.Array, salt, scale, offset=0) -> jax.Array:
    g = gaussian_from_salt(x.shape, jnp.asarray(salt, jnp.uint32), offset)
    return (x.astype(jnp.float32) + jnp.float32(scale) * g).astype(x.dtype)


def ref_zo_reconstruct(n: int, salts, coeffs, offset=0,
                       acc_dtype=jnp.float32) -> jax.Array:
    """``acc_dtype`` rounds the accumulator after each worker, mirroring the
    kernel's (and the DirectionEngine accumulators') per-worker semantics."""
    adt = jnp.dtype(acc_dtype)
    acc = jnp.zeros((n,), jnp.float32)
    for w in range(salts.shape[0]):
        g = gaussian_from_salt((n,), jnp.asarray(salts[w], jnp.uint32), offset)
        acc = (acc + coeffs[w] * g).astype(adt).astype(jnp.float32)
    return acc
