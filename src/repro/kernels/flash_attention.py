"""Flash attention Pallas-TPU kernel: blocked online softmax.

Supports the whole feature matrix of the assigned archs: causal masking,
sliding window (gemma2 local layers / long-context variants), gemma2 logit
soft-capping, and GQA (kv head = q head // group).

VMEM tiling: (block_q x hd) query tile streams over (block_k x hd) key/value
tiles along the innermost sequential grid dim; running max / denominator /
accumulator live in VMEM scratch across that dim.  Blocks are MXU-aligned
(128 default).  Fully-masked key blocks are skipped via ``@pl.when`` — with
a sliding window this is what makes prefill O(S*W) instead of O(S^2).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, block_q: int, block_k: int, n_kb: int, causal: bool,
    window: Optional[int], softcap: Optional[float], scale: float,
):
    qb = pl.program_id(1)
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qb * block_q
    k_start = kb * block_k

    # block-level skip: this key block is live iff some (i, j) pair passes
    # causal (j <= i) and window (i - j < W) tests for the block extents
    live = True
    if causal:
        live = jnp.logical_and(live, k_start <= q_start + block_q - 1)
    if window is not None:
        live = jnp.logical_and(live, (q_start - (k_start + block_k - 1)) < window)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, hd)
        k = k_ref[0].astype(jnp.float32)                  # (bk, hd)
        v = v_ref[0].astype(jnp.float32)                  # (bk, hd)
        s = q @ k.T                                       # (bq, bk)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        qi = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kj = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        rel = qi - kj
        mask = jnp.ones_like(rel, dtype=bool)
        if causal:
            mask &= rel >= 0
        if window is not None:
            mask &= rel < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + p @ v
        m_scr[...] = m_new

    @pl.when(kb == n_kb - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,            # (BH, Sq, hd)  — batch*q_heads flattened
    k: jax.Array,            # (BH, Sk, hd)  — kv heads pre-expanded to BH
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: Optional[bool] = None,
) -> jax.Array:
    # None auto-detects like kernels.ops.INTERPRET (resolved here, not at
    # import, to avoid a circular import with ops): callers bypassing ops
    # get interpret mode on CPU and Mosaic on TPU instead of silently
    # interpreting on real hardware.
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    BH, Sq, hd = q.shape
    Sk = k.shape[1]
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0
    n_qb, n_kb = Sq // block_q, Sk // block_k
    scale = 1.0 / (hd ** 0.5)
    kern = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, n_kb=n_kb,
        causal=causal, window=window, softcap=softcap, scale=scale,
    )
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        grid=(BH, n_qb, n_kb),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denominator
            pltpu.VMEM((block_q, hd), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
