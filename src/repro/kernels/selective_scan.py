"""Mamba-1 selective-scan Pallas kernel.

TPU adaptation of the CUDA fused scan: the (d_inner, n) running state lives
in VMEM scratch and persists across sequential sequence-block grid steps, so
the (B, S, d_inner, n) intermediate the pure-jnp associative scan
materializes (see models/ssm.py) never touches HBM.  HBM traffic drops from
O(S*di*n) to O(S*(di + n)) — the memory-roofline win quantified in
EXPERIMENTS.md §Perf.

Layout: channels tiled (block_d), sequence tiled (block_s, sequential), time
recurrence is an in-register ``fori_loop`` over the block's steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(u_ref, dt_ref, b_ref, c_ref, a_ref, d_ref, o_ref, h_scr,
                 *, block_s: int):
    sb = pl.program_id(2)

    @pl.when(sb == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    A = a_ref[...].astype(jnp.float32)            # (bd, n)
    Dp = d_ref[...].astype(jnp.float32)           # (bd,)
    u = u_ref[0].astype(jnp.float32)              # (bs, bd)
    dt = dt_ref[0].astype(jnp.float32)            # (bs, bd)
    Bm = b_ref[0].astype(jnp.float32)             # (bs, n)
    Cm = c_ref[0].astype(jnp.float32)             # (bs, n)

    def step(t, carry):
        h = carry                                  # (bd, n)
        dt_t = jax.lax.dynamic_slice_in_dim(dt, t, 1, 0)[0]   # (bd,)
        u_t = jax.lax.dynamic_slice_in_dim(u, t, 1, 0)[0]
        B_t = jax.lax.dynamic_slice_in_dim(Bm, t, 1, 0)[0]    # (n,)
        C_t = jax.lax.dynamic_slice_in_dim(Cm, t, 1, 0)[0]
        dA = jnp.exp(dt_t[:, None] * A)                       # (bd, n)
        dBu = (dt_t * u_t)[:, None] * B_t[None, :]
        h = dA * h + dBu
        y_t = jnp.sum(h * C_t[None, :], axis=1) + Dp * u_t    # (bd,)
        o_ref[0, t, :] = y_t.astype(o_ref.dtype)
        return h

    h_scr[...] = jax.lax.fori_loop(0, block_s, step, h_scr[...])


def selective_scan_pallas(
    u: jax.Array,      # (B, S, di) — post-conv, post-silu activations
    dt: jax.Array,     # (B, S, di) — softplus'd timestep
    Bmat: jax.Array,   # (B, S, n)
    Cmat: jax.Array,   # (B, S, n)
    A: jax.Array,      # (di, n) — negative decay matrix
    D: jax.Array,      # (di,)
    *,
    block_d: int = 256,
    block_s: int = 128,
    interpret: bool = True,
) -> jax.Array:
    B, S, di = u.shape
    n = A.shape[1]
    block_d = min(block_d, di)
    block_s = min(block_s, S)
    assert di % block_d == 0 and S % block_s == 0
    kern = functools.partial(_scan_kernel, block_s=block_s)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((B, S, di), u.dtype),
        # sequence dim must be innermost-sequential: state carries across it
        grid=(B, di // block_d, S // block_s),
        in_specs=[
            pl.BlockSpec((1, block_s, block_d), lambda b, d, s: (b, s, d)),
            pl.BlockSpec((1, block_s, block_d), lambda b, d, s: (b, s, d)),
            pl.BlockSpec((1, block_s, n), lambda b, d, s: (b, s, 0)),
            pl.BlockSpec((1, block_s, n), lambda b, d, s: (b, s, 0)),
            pl.BlockSpec((block_d, n), lambda b, d, s: (d, 0)),
            pl.BlockSpec((block_d,), lambda b, d, s: (d,)),
        ],
        out_specs=pl.BlockSpec((1, block_s, block_d), lambda b, d, s: (b, s, d)),
        scratch_shapes=[pltpu.VMEM((block_d, n), jnp.float32)],
        interpret=interpret,
    )(u, dt, Bmat, Cmat, A, D)
