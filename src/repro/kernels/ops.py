"""Public jit'd wrappers around the Pallas kernels.

On this CPU container the kernels execute in ``interpret=True`` mode (the
kernel body runs per-block in Python/XLA-CPU); on a real TPU runtime
``interpret=False`` lowers through Mosaic.  ``INTERPRET`` auto-detects.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas
from repro.kernels.selective_scan import selective_scan_pallas
from repro.kernels import zo_direction as zo_k

INTERPRET = jax.default_backend() == "cpu"


@partial(jax.jit, static_argnames=("eps", "block_rows"))
def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6,
            block_rows: int = 128) -> jax.Array:
    """Fused RMSNorm over the last dim; any leading shape."""
    lead = x.shape[:-1]
    flat = x.reshape(-1, x.shape[-1])
    rows = flat.shape[0]
    br = block_rows
    while rows % br:
        br //= 2
    out = rmsnorm_pallas(flat, scale, eps, max(br, 1), interpret=INTERPRET)
    return out.reshape(*lead, x.shape[-1])


@partial(jax.jit, static_argnames=("causal", "window", "softcap", "block_q", "block_k"))
def flash_attention(
    q: jax.Array,            # (B, Sq, H, hd)
    k: jax.Array,            # (B, Sk, KV, hd)
    v: jax.Array,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
) -> jax.Array:
    """GQA flash attention; returns (B, Sq, H, hd)."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    rep = H // KV
    qh = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd)
    kh = jnp.repeat(k.transpose(0, 2, 1, 3), rep, axis=1).reshape(B * H, -1, hd)
    vh = jnp.repeat(v.transpose(0, 2, 1, 3), rep, axis=1).reshape(B * H, -1, hd)
    out = flash_attention_pallas(
        qh, kh, vh, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, interpret=INTERPRET,
    )
    return out.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)


@partial(jax.jit, static_argnames=("block_d", "block_s"))
def selective_scan(u, dt, Bmat, Cmat, A, D, block_d: int = 256, block_s: int = 128):
    return selective_scan_pallas(
        u, dt, Bmat, Cmat, A, D, block_d=block_d, block_s=block_s,
        interpret=INTERPRET,
    )


@partial(jax.jit, static_argnames=("n", "block"))
def zo_sumsq(n: int, salt, offset=0, block: int = 4096):
    return zo_k.zo_sumsq(n, salt, offset, block=block, interpret=INTERPRET)


@partial(jax.jit, static_argnames=("block",))
def zo_perturb(x, salt, scale, offset=0, block: int = 4096):
    return zo_k.zo_perturb(x, salt, scale, offset, block=block, interpret=INTERPRET)


@partial(jax.jit, static_argnames=("n", "block", "acc_dtype"))
def zo_reconstruct(n: int, salts, coeffs, offset=0, block: int = 4096,
                   acc_dtype="float32"):
    return zo_k.zo_reconstruct(n, salts, coeffs, offset, block=block,
                               acc_dtype=jnp.dtype(acc_dtype),
                               interpret=INTERPRET)


# ---- flat (packed multi-leaf) kernels: one launch for the whole tree ---- #

@partial(jax.jit, static_argnames=("block",))
def zo_perturb_flat(x, salts, ctrs, nvalid, scale, block: int = 4096):
    return zo_k.zo_perturb_flat(x, salts, ctrs, nvalid, scale, block=block,
                                interpret=INTERPRET)


@partial(jax.jit, static_argnames=("block", "acc_dtype"))
def zo_reconstruct_flat(salts, coeffs, ctrs, nvalid, block: int = 4096,
                        acc_dtype="float32"):
    return zo_k.zo_reconstruct_flat(salts, coeffs, ctrs, nvalid, block=block,
                                    acc_dtype=jnp.dtype(acc_dtype),
                                    interpret=INTERPRET)


@partial(jax.jit, static_argnames=("block",))
def zo_perturb_sumsq(x, salts, ctrs, nvalid, mu, block: int = 4096):
    return zo_k.zo_perturb_sumsq(x, salts, ctrs, nvalid, mu, block=block,
                                 interpret=INTERPRET)


@partial(jax.jit, static_argnames=("momentum", "block", "acc_dtype"),
         donate_argnums=(0, 1))
def zo_reconstruct_update(p, mom, salts, ctrs, nvalid, bf16_mask, coeffs, lr,
                          momentum: float = 0.0, block: int = 4096,
                          acc_dtype="float32"):
    """Fused reconstruct+SGD commit.  ``p``/``mom`` are donated (the kernel
    aliases them in place); when called under an outer jit the donation is
    simply inherited from the caller."""
    return zo_k.zo_reconstruct_update(
        p, mom, salts, ctrs, nvalid, bf16_mask, coeffs, lr,
        momentum=momentum, block=block, acc_dtype=jnp.dtype(acc_dtype),
        interpret=INTERPRET)
