"""The paper-specific Pallas kernels: fused ZO direction generate+apply.

A ZO iteration is purely memory-bound (stream the d parameters twice: once
to perturb, once to update).  The naive jnp path writes the random direction
``v`` to HBM between generation and use; these kernels regenerate ``v``
on the fly inside the tile (the hash of repro.core.directions, bit-identical)
so the direction never exists in HBM:

* ``zo_sumsq``       — sum of squares of a hashed Gaussian block (for the
                       unit-sphere normalization), zero HBM reads.
* ``zo_perturb``     — ``x + (mu * inv_norm) * v``: one read + one write of x.
* ``zo_reconstruct`` — ``acc += sum_i coeff_i * v_i`` for all m workers in a
                       single pass over the parameters (m gaussians per
                       element generated in registers).

``offset`` shifts the leaf-local hash counter: the optimizer hashes each
leaf with its own salt and counters starting at 0, the grid shifts each
block by ``i * block`` internally, and callers that split one leaf across
multiple kernel calls pass the chunk's start index (whole-leaf calls pass
0 — see tests/test_directions.py::test_offset_split_consistency).

Arbitrary leaf sizes are supported: the grid is ``ceil(n / block)`` and the
tail block is masked.  Reductions (``zo_sumsq``) mask explicitly in-kernel —
hash values exist for any counter, so out-of-range lanes would otherwise
contribute garbage; elementwise outputs (``zo_perturb``/``zo_reconstruct``)
rely on Pallas's boundary semantics (out-of-bounds stores of a partial
output block are dropped, both in interpret mode and under Mosaic).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.directions import _GOLDEN, _SALT2, _TWO_PI, _XOR2, _uniform01, mix32


def _gauss_block(start: jax.Array, n: int, salt: jax.Array) -> jax.Array:
    """n standard normals for flat counters [start, start+n) (Box–Muller)."""
    idx = jax.lax.iota(jnp.uint32, n) + start
    h1 = mix32(idx * _GOLDEN + salt)
    h2 = mix32(idx * _SALT2 + (salt ^ _XOR2))
    u1 = _uniform01(h1)
    u2 = _uniform01(h2)
    return jnp.sqrt(-2.0 * jnp.log(u1)) * jnp.cos(_TWO_PI * u2)


def _grid(n: int, block: int) -> int:
    return (n + block - 1) // block


# --------------------------------------------------------------------------- #
def _sumsq_kernel(meta_ref, o_ref, *, block: int, n: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    salt = meta_ref[0].astype(jnp.uint32)
    offset = meta_ref[1].astype(jnp.uint32)
    g = _gauss_block(offset + jnp.uint32(i * block), block, salt)
    # tail mask: the hash yields (garbage) values for any counter, so lanes
    # past the leaf end must be excluded from the reduction explicitly
    lane = jax.lax.iota(jnp.int32, block) + i * block
    o_ref[0] += jnp.sum(jnp.where(lane < n, g * g, 0.0))


def zo_sumsq(n: int, salt, offset=0, block: int = 4096, interpret: bool = True) -> jax.Array:
    """||v_leaf||^2 for a hashed Gaussian leaf of n elements (no HBM input)."""
    block = min(block, n)
    meta = jnp.asarray([salt, offset], jnp.uint32)
    out = pl.pallas_call(
        functools.partial(_sumsq_kernel, block=block, n=n),
        out_shape=jax.ShapeDtypeStruct((1,), jnp.float32),
        grid=(_grid(n, block),),
        in_specs=[pl.BlockSpec((2,), lambda i: (0,))],
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        interpret=interpret,
    )(meta)
    return out[0]


# --------------------------------------------------------------------------- #
def _perturb_kernel(x_ref, meta_ref, scale_ref, o_ref, *, block: int):
    i = pl.program_id(0)
    salt = meta_ref[0].astype(jnp.uint32)
    offset = meta_ref[1].astype(jnp.uint32)
    g = _gauss_block(offset + jnp.uint32(i * block), block, salt)
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] = (x + scale_ref[0] * g).astype(o_ref.dtype)


def zo_perturb(
    x: jax.Array,        # flat (n,) parameter leaf
    salt,
    scale,               # mu * inv_norm (fp32 scalar)
    offset=0,
    block: int = 4096,
    interpret: bool = True,
) -> jax.Array:
    n = x.shape[0]
    block = min(block, n)
    meta = jnp.asarray([salt, offset], jnp.uint32)
    return pl.pallas_call(
        functools.partial(_perturb_kernel, block=block),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        grid=(_grid(n, block),),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((2,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        interpret=interpret,
    )(x, meta, jnp.asarray([scale], jnp.float32))


# --------------------------------------------------------------------------- #
def _reconstruct_kernel(salts_ref, coeffs_ref, off_ref, o_ref, *, block: int,
                        m: int, acc_dtype):
    i = pl.program_id(0)
    start = off_ref[0].astype(jnp.uint32) + jnp.uint32(i * block)
    acc = jnp.zeros((block,), jnp.float32)
    for w in range(m):  # static worker unroll: m gaussians live in registers
        g = _gauss_block(start, block, salts_ref[w].astype(jnp.uint32))
        acc = acc + coeffs_ref[w] * g
        if acc_dtype != jnp.float32:
            # round to the accumulator dtype after every worker — the exact
            # semantics of the tree/fused accumulators, so a bf16 acc_dtype
            # stays bit-identical across DirectionEngine backends
            acc = acc.astype(acc_dtype).astype(jnp.float32)
    o_ref[...] = acc


def zo_reconstruct(
    n: int,
    salts: jax.Array,    # (m,) uint32 — per-worker leaf salts
    coeffs: jax.Array,   # (m,) fp32   — c_i * inv_norm_i, pre-scaled
    offset=0,
    block: int = 4096,
    acc_dtype=jnp.float32,
    interpret: bool = True,
) -> jax.Array:
    """sum_i coeffs_i * v_i for one flat leaf, one pass, no HBM directions.

    ``acc_dtype`` rounds the running accumulator after each worker (still in
    registers — never in HBM), matching the optimizer's acc_dtype knob.
    """
    m = salts.shape[0]
    block = min(block, n)
    return pl.pallas_call(
        functools.partial(_reconstruct_kernel, block=block, m=m,
                          acc_dtype=jnp.dtype(acc_dtype)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        grid=(_grid(n, block),),
        in_specs=[
            pl.BlockSpec((m,), lambda i: (0,)),
            pl.BlockSpec((m,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        interpret=interpret,
    )(salts, coeffs, jnp.asarray([offset], jnp.uint32))
