"""The paper-specific Pallas kernels: fused ZO direction generate+apply.

A ZO iteration is purely memory-bound (stream the d parameters twice: once
to perturb, once to update).  The naive jnp path writes the random direction
``v`` to HBM between generation and use; these kernels regenerate ``v``
on the fly inside the tile (the hash of repro.core.directions, bit-identical)
so the direction never exists in HBM:

* ``zo_sumsq``       — sum of squares of a hashed Gaussian block (for the
                       unit-sphere normalization), zero HBM reads.
* ``zo_perturb``     — ``x + (mu * inv_norm) * v``: one read + one write of x.
* ``zo_reconstruct`` — ``acc += sum_i coeff_i * v_i`` for all m workers in a
                       single pass over the parameters (m gaussians per
                       element generated in registers).

The per-leaf kernels above take one ``(salt, offset)`` pair per call, so the
optimizer hot path launches one kernel per parameter leaf.  The *flat*
kernels below operate on the whole tree packed into ONE contiguous f32
buffer with block-aligned leaves, consuming per-BLOCK metadata arrays
(salt, leaf-local counter start, valid-lane count — built once by
``repro.core.engine.FlatEngine``), so a full multi-leaf primitive is a
single kernel launch:

* ``zo_perturb_flat``     — one launch for the whole tree's perturbation.
* ``zo_reconstruct_flat`` — one launch for the whole tree's m-worker
                            reconstruction.
* ``zo_perturb_sumsq``    — the fused perturb: a two-phase grid over the
                            same call first accumulates the tree-wide
                            ``sum(v^2)`` (zero HBM traffic — this is the
                            ``zo_sumsq`` algebra, finally on the hot path),
                            then writes ``x + mu * rsqrt(sumsq) * v`` with
                            the scale computed in-kernel.  HBM traffic: one
                            read + one write of x; the separate inv-norm
                            pass over d disappears.
* ``zo_reconstruct_update`` — the fused optimizer commit: regenerates all
                            m directions in registers, applies the
                            pre-scaled coefficients, and performs the
                            SGD(+momentum) update in the same pass.  Params
                            (and momentum) are read once and written once
                            via ``input_output_aliases`` (in-place on the
                            donated buffer); the update vector never exists
                            in HBM.

``offset`` shifts the leaf-local hash counter: the optimizer hashes each
leaf with its own salt and counters starting at 0, the grid shifts each
block by ``i * block`` internally, and callers that split one leaf across
multiple kernel calls pass the chunk's start index (whole-leaf calls pass
0 — see tests/test_directions.py::test_offset_split_consistency).

Arbitrary leaf sizes are supported: the grid is ``ceil(n / block)`` and the
tail block is masked.  Reductions (``zo_sumsq``) mask explicitly in-kernel —
hash values exist for any counter, so out-of-range lanes would otherwise
contribute garbage; elementwise outputs (``zo_perturb``/``zo_reconstruct``)
rely on Pallas's boundary semantics (out-of-bounds stores of a partial
output block are dropped, both in interpret mode and under Mosaic).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.directions import _GOLDEN, _SALT2, _TWO_PI, _XOR2, _uniform01, mix32


def _gauss_block(start: jax.Array, n: int, salt: jax.Array) -> jax.Array:
    """n standard normals for flat counters [start, start+n) (Box–Muller)."""
    idx = jax.lax.iota(jnp.uint32, n) + start
    h1 = mix32(idx * _GOLDEN + salt)
    h2 = mix32(idx * _SALT2 + (salt ^ _XOR2))
    u1 = _uniform01(h1)
    u2 = _uniform01(h2)
    return jnp.sqrt(-2.0 * jnp.log(u1)) * jnp.cos(_TWO_PI * u2)


def _grid(n: int, block: int) -> int:
    return (n + block - 1) // block


# --------------------------------------------------------------------------- #
def _sumsq_kernel(meta_ref, o_ref, *, block: int, n: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    salt = meta_ref[0].astype(jnp.uint32)
    offset = meta_ref[1].astype(jnp.uint32)
    g = _gauss_block(offset + jnp.uint32(i * block), block, salt)
    # tail mask: the hash yields (garbage) values for any counter, so lanes
    # past the leaf end must be excluded from the reduction explicitly
    lane = jax.lax.iota(jnp.int32, block) + i * block
    o_ref[0] += jnp.sum(jnp.where(lane < n, g * g, 0.0))


def zo_sumsq(n: int, salt, offset=0, block: int = 4096, interpret: bool = True) -> jax.Array:
    """||v_leaf||^2 for a hashed Gaussian leaf of n elements (no HBM input)."""
    block = min(block, n)
    meta = jnp.asarray([salt, offset], jnp.uint32)
    out = pl.pallas_call(
        functools.partial(_sumsq_kernel, block=block, n=n),
        out_shape=jax.ShapeDtypeStruct((1,), jnp.float32),
        grid=(_grid(n, block),),
        in_specs=[pl.BlockSpec((2,), lambda i: (0,))],
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        interpret=interpret,
    )(meta)
    return out[0]


# --------------------------------------------------------------------------- #
def _perturb_kernel(x_ref, meta_ref, scale_ref, o_ref, *, block: int):
    i = pl.program_id(0)
    salt = meta_ref[0].astype(jnp.uint32)
    offset = meta_ref[1].astype(jnp.uint32)
    g = _gauss_block(offset + jnp.uint32(i * block), block, salt)
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] = (x + scale_ref[0] * g).astype(o_ref.dtype)


def zo_perturb(
    x: jax.Array,        # flat (n,) parameter leaf
    salt,
    scale,               # mu * inv_norm (fp32 scalar)
    offset=0,
    block: int = 4096,
    interpret: bool = True,
) -> jax.Array:
    n = x.shape[0]
    block = min(block, n)
    meta = jnp.asarray([salt, offset], jnp.uint32)
    return pl.pallas_call(
        functools.partial(_perturb_kernel, block=block),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        grid=(_grid(n, block),),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((2,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        interpret=interpret,
    )(x, meta, jnp.asarray([scale], jnp.float32))


# --------------------------------------------------------------------------- #
def _reconstruct_kernel(salts_ref, coeffs_ref, off_ref, o_ref, *, block: int,
                        m: int, acc_dtype):
    i = pl.program_id(0)
    start = off_ref[0].astype(jnp.uint32) + jnp.uint32(i * block)
    acc = jnp.zeros((block,), jnp.float32)
    for w in range(m):  # static worker unroll: m gaussians live in registers
        g = _gauss_block(start, block, salts_ref[w].astype(jnp.uint32))
        acc = acc + coeffs_ref[w] * g
        if acc_dtype != jnp.float32:
            # round to the accumulator dtype after every worker — the exact
            # semantics of the tree/fused accumulators, so a bf16 acc_dtype
            # stays bit-identical across DirectionEngine backends
            acc = acc.astype(acc_dtype).astype(jnp.float32)
    o_ref[...] = acc


def zo_reconstruct(
    n: int,
    salts: jax.Array,    # (m,) uint32 — per-worker leaf salts
    coeffs: jax.Array,   # (m,) fp32   — c_i * inv_norm_i, pre-scaled
    offset=0,
    block: int = 4096,
    acc_dtype=jnp.float32,
    interpret: bool = True,
) -> jax.Array:
    """sum_i coeffs_i * v_i for one flat leaf, one pass, no HBM directions.

    ``acc_dtype`` rounds the running accumulator after each worker (still in
    registers — never in HBM), matching the optimizer's acc_dtype knob.
    """
    m = salts.shape[0]
    block = min(block, n)
    return pl.pallas_call(
        functools.partial(_reconstruct_kernel, block=block, m=m,
                          acc_dtype=jnp.dtype(acc_dtype)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        grid=(_grid(n, block),),
        in_specs=[
            pl.BlockSpec((m,), lambda i: (0,)),
            pl.BlockSpec((m,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        interpret=interpret,
    )(salts, coeffs, jnp.asarray([offset], jnp.uint32))


# --------------------------------------------------------------------------- #
# flat multi-leaf kernels: the whole tree in one packed buffer, one launch.
#
# Packed-buffer convention (repro.core.engine.FlatEngine): every leaf is
# padded to a multiple of ``block`` so each grid block belongs to exactly
# ONE leaf; per-block arrays carry that leaf's salt, the block's leaf-local
# counter start (b * block within its leaf — the same shift the per-leaf
# grid applies internally), and the number of valid lanes (tail blocks of a
# leaf mask the padding).  Hash identity is therefore bit-compatible with
# the per-leaf kernels and the jnp/tree backends: leaf-local counters from
# 0, one salt per (t, worker, leaf).
# --------------------------------------------------------------------------- #
def _valid_lanes(nv_ref, block: int):
    return jax.lax.iota(jnp.int32, block) < nv_ref[0]


def _perturb_flat_kernel(x_ref, salt_ref, ctr_ref, nv_ref, scale_ref, o_ref,
                         *, block: int):
    g = _gauss_block(ctr_ref[0].astype(jnp.uint32), block,
                     salt_ref[0].astype(jnp.uint32))
    x = x_ref[...]
    # padding lanes carry x through unchanged (zeros stay zeros)
    o_ref[...] = jnp.where(_valid_lanes(nv_ref, block),
                           x + scale_ref[0] * g, x)


def zo_perturb_flat(
    x: jax.Array,        # (P,) packed f32 parameter buffer (block-aligned)
    salts: jax.Array,    # (nb,) uint32 — per-block leaf salt
    ctrs: jax.Array,     # (nb,) uint32 — per-block leaf-local counter start
    nvalid: jax.Array,   # (nb,) int32  — valid lanes per block
    scale,               # mu * inv_norm (fp32 scalar, premultiplied)
    block: int = 4096,
    interpret: bool = True,
) -> jax.Array:
    """Whole-tree ``x + scale * v`` in ONE kernel launch (vs one per leaf)."""
    nb = salts.shape[0]
    assert x.shape[0] == nb * block, (x.shape, nb, block)
    return pl.pallas_call(
        functools.partial(_perturb_flat_kernel, block=block),
        out_shape=jax.ShapeDtypeStruct((nb * block,), jnp.float32),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        interpret=interpret,
    )(x, salts, ctrs, nvalid, jnp.asarray(scale, jnp.float32).reshape(1))


def _reconstruct_flat_kernel(salts_ref, coeffs_ref, ctr_ref, nv_ref, o_ref,
                             *, block: int, m: int, acc_dtype):
    start = ctr_ref[0].astype(jnp.uint32)
    acc = jnp.zeros((block,), jnp.float32)
    for w in range(m):  # static worker unroll: m gaussians live in registers
        g = _gauss_block(start, block, salts_ref[0, w].astype(jnp.uint32))
        acc = acc + coeffs_ref[w] * g
        if acc_dtype != jnp.float32:
            acc = acc.astype(acc_dtype).astype(jnp.float32)
    o_ref[...] = jnp.where(_valid_lanes(nv_ref, block), acc, 0.0)


def zo_reconstruct_flat(
    salts: jax.Array,    # (nb, m) uint32 — per-(block, worker) leaf salts
    coeffs: jax.Array,   # (m,) fp32 — c_i * inv_norm_i, pre-scaled
    ctrs: jax.Array,     # (nb,) uint32
    nvalid: jax.Array,   # (nb,) int32
    block: int = 4096,
    acc_dtype=jnp.float32,
    interpret: bool = True,
) -> jax.Array:
    """Whole-tree ``sum_i coeffs_i * v_i`` in ONE launch; padding lanes 0."""
    nb, m = salts.shape
    return pl.pallas_call(
        functools.partial(_reconstruct_flat_kernel, block=block, m=m,
                          acc_dtype=jnp.dtype(acc_dtype)),
        out_shape=jax.ShapeDtypeStruct((nb * block,), jnp.float32),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, m), lambda i: (i, 0)),
            pl.BlockSpec((m,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        interpret=interpret,
    )(salts, coeffs, ctrs, nvalid)


def _perturb_sumsq_kernel(x_ref, salt_ref, ctr_ref, nv_ref, mu_ref,
                          o_ref, ss_ref, *, block: int):
    p = pl.program_id(0)          # phase: 0 = accumulate sumsq, 1 = perturb
    i = pl.program_id(1)

    @pl.when((p == 0) & (i == 0))
    def _():
        ss_ref[...] = jnp.zeros_like(ss_ref)

    g = _gauss_block(ctr_ref[0].astype(jnp.uint32), block,
                     salt_ref[0].astype(jnp.uint32))
    valid = _valid_lanes(nv_ref, block)

    @pl.when(p == 0)
    def _():
        # tail mask: hash values exist for any counter, so padding lanes
        # must be excluded from the reduction explicitly
        ss_ref[0] += jnp.sum(jnp.where(valid, g * g, 0.0))

    @pl.when(p == 1)
    def _():
        # the tree-wide sumsq is fully accumulated (the grid is sequential),
        # so the unit-sphere scale is computed in-kernel — no separate
        # inv-norm pass over d
        scale = mu_ref[0] * jax.lax.rsqrt(ss_ref[0] + 1e-30)
        x = x_ref[...]
        o_ref[...] = jnp.where(valid, x + scale * g, x)


def zo_perturb_sumsq(
    x: jax.Array,        # (P,) packed f32 parameter buffer (block-aligned)
    salts: jax.Array,    # (nb,) uint32 — per-block leaf salt
    ctrs: jax.Array,     # (nb,) uint32
    nvalid: jax.Array,   # (nb,) int32
    mu,                  # smoothing parameter (fp32 scalar; NOT premultiplied)
    block: int = 4096,
    interpret: bool = True,
) -> tuple:
    """Fused ``(x + mu * rsqrt(sum v^2) * v, sum v^2)`` in one launch.

    A two-phase grid over one call: phase 0 streams NO HBM data (the
    direction is hash-generated) and accumulates the tree-wide ``sum(v^2)``
    into the scalar output; phase 1 re-generates each block's gaussians,
    reads x once and writes the perturbed buffer once with the scale
    ``mu * rsqrt(sumsq + 1e-30)`` computed in-kernel.  Returns
    ``(x_perturbed, sumsq)`` so the caller reuses the same ``inv_norm`` for
    the reconstruction coefficients.

    Note the kernel's blockwise-sequential reduction order differs from the
    shared jnp reduction of ``DirectionEngine.sumsq``, so the perturbed
    point may differ from the per-primitive path in the last ulp — the
    fused-step seam documented in README §DirectionEngine.
    """
    nb = salts.shape[0]
    assert x.shape[0] == nb * block, (x.shape, nb, block)
    # phase 0 never consumes x / the output block: pin both to block 0
    # (p * i) so no extra HBM pass happens during accumulation; phase 1
    # rewrites block 0 first, so the phase-0 garbage write never survives.
    return pl.pallas_call(
        functools.partial(_perturb_sumsq_kernel, block=block),
        out_shape=(jax.ShapeDtypeStruct((nb * block,), jnp.float32),
                   jax.ShapeDtypeStruct((1,), jnp.float32)),
        grid=(2, nb),
        in_specs=[
            pl.BlockSpec((block,), lambda p, i: (p * i,)),
            pl.BlockSpec((1,), lambda p, i: (i,)),
            pl.BlockSpec((1,), lambda p, i: (i,)),
            pl.BlockSpec((1,), lambda p, i: (i,)),
            pl.BlockSpec((1,), lambda p, i: (0,)),
        ],
        out_specs=(pl.BlockSpec((block,), lambda p, i: (p * i,)),
                   pl.BlockSpec((1,), lambda p, i: (0,))),
        interpret=interpret,
    )(x, salts, ctrs, nvalid, jnp.asarray(mu, jnp.float32).reshape(1))


def _reconstruct_update_kernel(p_ref, *refs, block: int, m: int, acc_dtype,
                               momentum: float, use_momentum: bool):
    if use_momentum:
        (v_ref, salts_ref, ctr_ref, nv_ref, bf16_ref, coeffs_ref, lr_ref,
         po_ref, vo_ref) = refs
    else:
        (salts_ref, ctr_ref, nv_ref, bf16_ref, coeffs_ref, lr_ref,
         po_ref) = refs
    start = ctr_ref[0].astype(jnp.uint32)
    acc = jnp.zeros((block,), jnp.float32)
    for w in range(m):  # static worker unroll: m gaussians live in registers
        g = _gauss_block(start, block, salts_ref[0, w].astype(jnp.uint32))
        acc = acc + coeffs_ref[w] * g
        if acc_dtype != jnp.float32:
            # round after every worker — the exact semantics of the
            # DirectionEngine accumulators (bit-identical under bf16 acc)
            acc = acc.astype(acc_dtype).astype(jnp.float32)
    # padding lanes contribute nothing: params/momentum padding stays 0
    acc = jnp.where(_valid_lanes(nv_ref, block), acc, 0.0)
    # optimizers.sgd computes deltas = -lr * v and apply_deltas adds them;
    # mirror that expression shape (p + (-lr)*v, not p - lr*v) so XLA's FMA
    # contraction matches the unfused path bit-for-bit
    neg_lr = -lr_ref[0]
    if use_momentum:
        # optimizers.sgd: v' = momentum * v + g;  p' = p + (-lr) * v'
        v_new = jnp.float32(momentum) * v_ref[...] + acc
        vo_ref[...] = v_new
        p_new = p_ref[...] + neg_lr * v_new
    else:
        p_new = p_ref[...] + neg_lr * acc
    # leaves stored in bf16 round-trip through their dtype on commit, the
    # apply_deltas semantics (per-block flag: each block is one leaf's)
    p_bf16 = p_new.astype(jnp.bfloat16).astype(jnp.float32)
    po_ref[...] = jnp.where(bf16_ref[0] != 0, p_bf16, p_new)


def zo_reconstruct_update(
    p: jax.Array,                  # (P,) packed f32 params (donated, aliased)
    mom,                           # (P,) packed f32 momentum, or None
    salts: jax.Array,              # (nb, m) uint32
    ctrs: jax.Array,               # (nb,) uint32
    nvalid: jax.Array,             # (nb,) int32
    bf16_mask: jax.Array,          # (nb,) int32 — 1 where the leaf is bf16
    coeffs: jax.Array,             # (m,) fp32 — fully pre-scaled
    lr,                            # learning rate (fp32 scalar)
    momentum: float = 0.0,
    block: int = 4096,
    acc_dtype=jnp.float32,
    interpret: bool = True,
):
    """Fused reconstruct + SGD(+momentum) commit: the update vector never
    exists in HBM.

    One pass: per block, all m directions are regenerated in registers and
    contracted with the pre-scaled ``coeffs`` (``c_i * inv_norm_i *
    zo_scale / m``, with per-worker ``acc_dtype`` rounding), then the
    SGD(+momentum) update runs in-kernel: params (and momentum) are read
    once and written once, in place (``input_output_aliases``).  Returns
    ``(p', mom')`` (``mom'`` is None when ``mom`` is None — the
    momentum-free optimizer carries no state buffer).
    """
    nb, m = salts.shape
    assert p.shape[0] == nb * block, (p.shape, nb, block)
    use_momentum = mom is not None
    kern = functools.partial(
        _reconstruct_update_kernel, block=block, m=m,
        acc_dtype=jnp.dtype(acc_dtype), momentum=float(momentum),
        use_momentum=use_momentum)
    blk = pl.BlockSpec((block,), lambda i: (i,))
    meta_specs = [
        pl.BlockSpec((1, m), lambda i: (i, 0)),
        pl.BlockSpec((1,), lambda i: (i,)),
        pl.BlockSpec((1,), lambda i: (i,)),
        pl.BlockSpec((1,), lambda i: (i,)),
        pl.BlockSpec((m,), lambda i: (0,)),
        pl.BlockSpec((1,), lambda i: (0,)),
    ]
    lr_arr = jnp.asarray(lr, jnp.float32).reshape(1)
    shape = jax.ShapeDtypeStruct((nb * block,), jnp.float32)
    if use_momentum:
        p_out, v_out = pl.pallas_call(
            kern,
            out_shape=(shape, shape),
            grid=(nb,),
            in_specs=[blk, blk] + meta_specs,
            out_specs=(blk, blk),
            input_output_aliases={0: 0, 1: 1},   # in-place: read+write once
            interpret=interpret,
        )(p, mom, salts, ctrs, nvalid, bf16_mask, coeffs, lr_arr)
        return p_out, v_out
    p_out = pl.pallas_call(
        kern,
        out_shape=shape,
        grid=(nb,),
        in_specs=[blk] + meta_specs,
        out_specs=blk,
        input_output_aliases={0: 0},             # in-place: read+write once
        interpret=interpret,
    )(p, salts, ctrs, nvalid, bf16_mask, coeffs, lr_arr)
    return p_out, None
