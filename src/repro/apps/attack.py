"""§5.1 experiment: universal adversarial example generation (Appendix A).

The attack optimizes a single universal perturbation x (d = 900) over K
natural images with the Carlini–Wagner-style loss

  F(x, zeta_k) = c * max{0, f_y(z_k) - max_{j!=y} f_j(z_k)} + ||z_k - a_k||^2,
  z_k = 0.5 * tanh(atanh(2 a_k) + x),

treating the trained DNN as a black box for the ZO methods (only function
evaluations) — exactly the setting where HO-SGD's hybrid schedule pays off.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import make_digits
from repro.models.mlp import init_mlp_classifier, mlp_accuracy, mlp_logits
from repro.opt.optimizers import adam, apply_deltas, const_schedule


def train_victim(key, side: int = 30, n_classes: int = 10, hidden: int = 128,
                 steps: int = 300, n_train: int = 4096) -> Tuple[Dict, float]:
    """Train the small digit classifier the attack targets."""
    x, y = make_digits(n=n_train, side=side, n_classes=n_classes, seed=1)
    params = init_mlp_classifier(key, side * side, n_classes, hidden=hidden)
    opt = adam(const_schedule(1e-3))
    state = opt.init(params)

    def loss(p, xb, yb):
        lg = mlp_logits(p, xb)
        lse = jax.nn.logsumexp(lg, -1)
        gold = jnp.take_along_axis(lg, yb[:, None], -1)[:, 0]
        return jnp.mean(lse - gold)

    @jax.jit
    def step(p, s, xb, yb, t):
        l, g = jax.value_and_grad(loss)(p, xb, yb)
        deltas, s = opt.update(g, s, p, t)
        return apply_deltas(p, deltas), s, l

    rng = np.random.default_rng(0)
    for t in range(steps):
        idx = rng.integers(0, n_train, 256)
        params, state, l = step(params, state, x[idx], y[idx], t)
    acc = float(mlp_accuracy(params, {"x": x, "y": y}))
    return params, acc


def make_attack_loss(victim: Dict, c: float = 1.0):
    """Returns loss_fn(params={'x': perturbation}, batch={'a','y'})."""

    def z_of(x, a):
        return 0.5 * jnp.tanh(jnp.arctanh(jnp.clip(2 * a, -0.999, 0.999)) + x)

    def loss_fn(params, batch):
        x = params["x"]
        a, y = batch["a"], batch["y"]
        z = z_of(x, a)
        logits = mlp_logits(victim, z)
        gold = jnp.take_along_axis(logits, y[:, None], -1)[:, 0]
        others = jnp.where(
            jax.nn.one_hot(y, logits.shape[-1], dtype=bool), -jnp.inf, logits
        ).max(-1)
        margin = jnp.maximum(0.0, gold - others)
        dist = jnp.sum((z - a) ** 2, -1)
        return jnp.mean(c * margin + dist)

    return loss_fn, z_of


def attack_metrics(victim: Dict, z_of, params, images, labels) -> Dict[str, float]:
    z = z_of(params["x"], images)
    preds = jnp.argmax(mlp_logits(victim, z), -1)
    success = preds != labels
    l2 = jnp.sqrt(jnp.sum((z - images) ** 2, -1))
    return {
        "success_rate": float(jnp.mean(success)),
        "l2_distortion": float(jnp.mean(jnp.where(success, l2, jnp.nan))
                               if bool(jnp.any(success)) else jnp.mean(l2)),
        "l2_all": float(jnp.mean(l2)),
    }
