from repro.apps.attack import (  # noqa: F401
    attack_metrics, make_attack_loss, train_victim,
)
from repro.apps.classification import load_dataset, run_comparison  # noqa: F401
