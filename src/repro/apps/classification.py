"""§5.2 experiment: multi-class classification with the 1.69M-param MLP,
comparing HO-SGD against all baselines on four datasets."""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.core import (
    HOSGDConfig, make_ho_sgd, make_pa_sgd, make_qsgd, make_ri_sgd,
    make_sync_sgd, make_zo_sgd, make_zo_svrg_ave,
)
from repro.data.synthetic import Dataset, batches, make_classification
from repro.data.libsvm import try_load
from repro.metrics import MeterRegistry
from repro.models.mlp import init_mlp_classifier, mlp_accuracy, mlp_loss


def load_dataset(name: str, n_train: int = 8192) -> Dataset:
    real = try_load(name)
    return real if real is not None else make_classification(name, n_train=n_train)


def build_methods(m: int, tau: int, lr: float, zo_lr: float, mu: float,
                  dataset_batch, which: Optional[List[str]] = None) -> Dict:
    all_methods = {
        "ho_sgd": lambda: make_ho_sgd(
            mlp_loss, HOSGDConfig(tau=tau, mu=mu, m=m, lr=lr, zo_lr=zo_lr)),
        "sync_sgd": lambda: make_sync_sgd(mlp_loss, m, lr=lr),
        "ri_sgd": lambda: make_ri_sgd(mlp_loss, m, tau=tau, lr=lr, mu_r=0.25),
        "pa_sgd": lambda: make_pa_sgd(mlp_loss, m, tau=tau, lr=lr),
        "zo_sgd": lambda: make_zo_sgd(mlp_loss, m, mu=mu, lr=zo_lr),
        "zo_svrg_ave": lambda: make_zo_svrg_ave(
            mlp_loss, m, mu=mu, lr=zo_lr, dataset=dataset_batch),
        "qsgd": lambda: make_qsgd(mlp_loss, m, s=8, lr=lr),
    }
    which = which or list(all_methods)
    return {k: all_methods[k]() for k in which}


def run_comparison(
    dataset_name: str,
    n_iters: int = 200,
    m: int = 4,
    B: int = 64,
    tau: int = 8,
    hidden: int = 1300,            # the paper's 1.3K+1.3K hidden, d>1.69M
    lr: float = 0.05,
    mu: float = 1e-3,
    methods: Optional[List[str]] = None,
    seed: int = 0,
    eval_every: int = 20,
) -> Dict[str, Dict]:
    ds = load_dataset(dataset_name)
    params0 = init_mlp_classifier(
        jax.random.key(seed), ds.n_features, ds.n_classes, hidden=hidden)
    d = sum(int(x.size) for x in jax.tree.leaves(params0))
    zo_lr = lr * 30.0 / d          # the paper's 30/d step-size scaling
    anchor = {"x": ds.x_train[:1024], "y": ds.y_train[:1024]}
    meths = build_methods(m, tau, lr, zo_lr, mu, anchor, methods)

    results = {}
    test = {"x": ds.x_test, "y": ds.y_test}
    for name, meth in meths.items():
        params, state = params0, meth.init(params0)
        meter = MeterRegistry(d)
        hist = {"loss": [], "acc": [], "iter_s": []}
        key = jax.random.key(seed)
        data = batches(ds, m * B, seed=seed + 1)
        t0 = time.perf_counter()
        for t in range(n_iters):
            batch = next(data)
            ts = time.perf_counter()
            params, state, metrics = meth.step(t, params, state, batch, key)
            hist["iter_s"].append(time.perf_counter() - ts)
            hist["loss"].append(float(metrics["loss"]))
            meter.tick(meth)
            if (t + 1) % eval_every == 0 or t == n_iters - 1:
                hist["acc"].append((t + 1, float(mlp_accuracy(params, test))))
        hist["wall_s"] = time.perf_counter() - t0
        hist["meter"] = meter.summary()
        hist["final_acc"] = hist["acc"][-1][1]
        hist["final_loss"] = float(np.mean(hist["loss"][-10:]))
        results[name] = hist
    return results
