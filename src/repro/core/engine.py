"""DirectionEngine: the one home of the ZO direction algebra.

Before this module the same four primitives — direction norm, parameter
perturbation, the scalar ZO coefficient, and the update reconstruction —
were implemented four times (tree-materialized jnp in ``core.ho_sgd`` /
``core.zo_grad``, fused XLA closures private to ``core.distributed``, the
Pallas kernels in ``kernels.zo_direction``, and the oracles in
``kernels.ref``).  Every consumer now goes through a ``DirectionEngine``;
backends are interchangeable and adding one (real-TPU Mosaic, bf16
accumulators, fused optimizer updates) is a one-file change.

Backends
--------
* ``tree``   — the readable jnp reference: materializes the whole raw
               direction tree per primitive and maps over it; the worker
               loop in ``reconstruct`` is statically unrolled (HLO O(m)).
* ``fused``  — the production XLA formulation (lifted out of the old
               ``make_zo_step`` closures): per-leaf generation inlined into
               the consuming op so the direction never exists as a program
               buffer, worker loop as ``fori_loop`` (HLO O(1) in m).
* ``pallas`` — routes ``perturb``/``reconstruct`` through the
               ``kernels.ops`` Pallas kernels: the direction is regenerated
               inside the tile and never touches HBM; all m workers are
               reconstructed in one pass over the parameters.
* ``flat``   — packs the tree into ONE contiguous block-aligned f32 buffer
               and runs one multi-leaf kernel per primitive (vs one per
               leaf), plus a fused step path (perturb+sumsq in one launch,
               reconstruct+SGD-commit in one launch on donated buffers) used
               by ``core.ho_sgd``/``core.distributed`` when the optimizer is
               plain SGD(+momentum).

Contract (see README §DirectionEngine)
--------------------------------------
* Directions are the hashed gaussians of ``repro.core.directions``: leaf i
  of worker w at iteration t uses salt ``fold(seed, t, w, i)`` with
  leaf-local counters starting at 0 — bit-compatibility REQUIRES leaf-local
  counters (the kernels' ``offset`` argument shifts the intra-leaf counter
  when one leaf is split across calls; whole-leaf calls pass 0, and the
  grid blocks shift by ``i * block`` internally).  The engine precomputes
  per-leaf ``(salt_index, offset)`` metadata at construction — ``offsets``
  records each leaf's base index in the flat d-dim vector, layout metadata
  for backends that pack the tree into one flat buffer (such a backend
  still hashes each leaf with its own salt from counter 0).  Salts depend
  on traced ``(t, worker)`` and are folded per call.
* ``inv_norm`` is computed by the shared jnp reduction in *every* backend,
  so the perturb scale and reconstruction coefficients are bit-identical
  across backends by construction (a kernel-side ``zo_sumsq`` exists but
  changes the reduction order; it stays a benchmarking primitive).
* ``perturb(params, t, w, scale)`` applies ``x_f32 + scale * v_raw`` cast
  back to ``x.dtype`` — ``scale`` is the premultiplied fp32
  ``mu * inv_norm``, so every backend applies the identical elementwise
  expression.
* ``reconstruct(coeffs, t)`` returns ``sum_w (coeffs[w] * inv_norm_w) *
  v_raw_w`` as an fp32 tree, rounding the accumulator to ``acc_dtype``
  after each worker (the distributed semantics; callers apply the final
  ``zo_scale / m``).
* Sharding hooks: ``specs`` (per-leaf PartitionSpecs, or a matching tree)
  are applied to every generated direction leaf and accumulator, so the
  partitioner can never replicate a hash-generated tree (the O(d)-per-
  device failure mode of unconstrained iota).
* Bit-equality caveat: backends evaluate the identical algebra, but XLA's
  transcendental vectorization is shape-dependent, so the Pallas backend is
  bitwise equal to tree/fused only when its tile covers the whole leaf;
  sub-leaf tiles may differ in the last ulp (the equivalence suite pins
  both regimes).
"""
from __future__ import annotations

import math
from typing import Any, Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import directions as D


def _as_worker(w) -> jax.Array:
    return jnp.asarray(w, jnp.uint32)


class DirectionEngine:
    """Base class: shared metadata, norm algebra, and the coefficient eval."""

    name = "base"

    def __init__(self, params_like: Any, seed: int, *, specs: Any = None,
                 acc_dtype: Any = "float32", block: int = 4096):
        leaves, self.treedef = jax.tree.flatten(params_like)
        self.shapes: List[Tuple[int, ...]] = [tuple(x.shape) for x in leaves]
        self.dtypes = [jnp.dtype(x.dtype) for x in leaves]
        self.sizes = [int(math.prod(s)) for s in self.shapes]
        # per-leaf base index in the flat d-dim vector: layout metadata for
        # backends that pack the tree into one flat buffer.  NOT a hash
        # counter — counters are leaf-local (see the module docstring).
        self.offsets: List[int] = []
        off = 0
        for n in self.sizes:
            self.offsets.append(off)
            off += n
        self.dim = off
        self.seed = seed
        self.acc_dtype = jnp.dtype(acc_dtype)
        self.block = block
        if specs is None:
            self.specs: List[Optional[P]] = [None] * len(leaves)
        elif isinstance(specs, (list, tuple)):
            self.specs = list(specs)
        else:
            self.specs = jax.tree.leaves(
                specs, is_leaf=lambda x: x is None or isinstance(x, P))
        assert len(self.specs) == len(leaves), \
            f"{len(self.specs)} specs for {len(leaves)} leaves"

    # ---- metadata ------------------------------------------------------- #
    def salts(self, t, worker) -> List[jax.Array]:
        """Per-leaf salts for (t, worker) — the hash identity of one v."""
        w = _as_worker(worker)
        return [D.fold(self.seed, t, w, i) for i in range(len(self.shapes))]

    def _constrain(self, x: jax.Array, i: int) -> jax.Array:
        s = self.specs[i]
        return x if s is None else jax.lax.with_sharding_constraint(x, s)

    def _gauss(self, i: int, salt: jax.Array) -> jax.Array:
        """Leaf i's raw (unnormalized) direction, sharding-constrained."""
        return self._constrain(D.gaussian_from_salt(self.shapes[i], salt), i)

    # ---- primitive 1: the unit-sphere normalization --------------------- #
    def sumsq(self, t, worker) -> jax.Array:
        """||v_raw||^2 over the whole tree — shared jnp reduction in every
        backend (keeps the scale bit-identical across backends)."""
        salts = self.salts(t, worker)
        return sum(
            jnp.sum(jnp.square(self._gauss(i, s))) for i, s in enumerate(salts)
        )

    def inv_norm(self, t, worker) -> jax.Array:
        return jax.lax.rsqrt(self.sumsq(t, worker) + 1e-30)

    # ---- primitive 2: perturb ------------------------------------------- #
    def perturb(self, params: Any, t, worker, scale) -> Any:
        """x + scale * v_raw per leaf, cast back to each leaf's dtype.

        ``scale`` is the premultiplied fp32 ``mu * inv_norm(t, worker)``.
        """
        raise NotImplementedError

    # ---- primitive 3: the scalar ZO coefficient (eq. 4) ----------------- #
    def zo_coeff(self, loss_fn: Callable[[Any, Any], jax.Array], params: Any,
                 batch: Any, t, worker, mu: float) -> Tuple[jax.Array, jax.Array]:
        """Two function evaluations -> (c, f0) with
        c = (d/mu) * [F(x + mu*v) - F(x)]."""
        inv = self.inv_norm(t, worker)
        f0 = loss_fn(params, batch)
        f1 = loss_fn(self.perturb(params, t, worker, jnp.float32(mu) * inv),
                     batch)
        return ((self.dim / mu) * (f1 - f0)).astype(jnp.float32), f0

    def zo_coeffs(self, loss_fn: Callable, params: Any, batches: Any, t,
                  workers: jax.Array, mu: float, *, vmap_workers: bool = False
                  ) -> Tuple[jax.Array, jax.Array]:
        """All m workers' coefficients; ``batches`` is worker-stacked
        (m, B, ...).  ``vmap_workers`` evaluates the m (perturb + loss)
        pairs under one vmap — HLO O(1) in m, at the cost of materializing
        one direction leaf per in-flight worker (the CPU-rehearsal trade)."""
        if vmap_workers:
            return jax.vmap(
                lambda w, b: self.zo_coeff(loss_fn, params, b, t, w, mu)
            )(workers, batches)
        cs, f0s = [], []
        for i in range(int(workers.shape[0])):
            b_i = jax.tree.map(lambda x: x[i], batches)
            c, f0 = self.zo_coeff(loss_fn, params, b_i, t, workers[i], mu)
            cs.append(c)
            f0s.append(f0)
        return jnp.stack(cs), jnp.stack(f0s)

    # ---- primitive 4: reconstruct --------------------------------------- #
    def reconstruct(self, coeffs: jax.Array, t, workers: Optional[jax.Array]
                    = None, *, vmap_workers: bool = False) -> Any:
        """sum_w (coeffs[w] * inv_norm_w) * v_raw_w as an fp32 tree.

        The accumulator is rounded to ``acc_dtype`` after every worker —
        the exact semantics of the distributed reconstruction.  With
        ``vmap_workers`` the per-worker terms are generated under one vmap
        and contracted (HLO O(1) in m; one fp32 sum, single final rounding —
        equal to the sequential path within accumulation-order tolerance).
        """
        m = int(coeffs.shape[0])
        if workers is None:
            workers = jnp.arange(m, dtype=jnp.uint32)
        coeffs = coeffs.astype(jnp.float32)
        if vmap_workers:
            return self._reconstruct_vmapped(coeffs, t, workers)
        return self._reconstruct(coeffs, t, workers)

    def _reconstruct(self, coeffs, t, workers) -> Any:
        raise NotImplementedError

    def _reconstruct_vmapped(self, coeffs, t, workers) -> Any:
        scaled = coeffs * jax.vmap(lambda w: self.inv_norm(t, w))(workers)
        outs = []
        for i in range(len(self.shapes)):
            gen = jax.vmap(
                lambda w, i=i: D.gaussian_from_salt(
                    self.shapes[i], D.fold(self.seed, t, _as_worker(w), i)))
            g = gen(workers)                                # (m, *leaf)
            acc = jnp.tensordot(scaled, g, axes=(0, 0))     # fp32 contraction
            outs.append(self._constrain(
                acc.astype(self.acc_dtype).astype(jnp.float32), i))
        return jax.tree.unflatten(self.treedef, outs)

    def _acc_init(self) -> List[jax.Array]:
        return [
            self._constrain(jnp.zeros(s, self.acc_dtype), i)
            for i, s in enumerate(self.shapes)
        ]


# --------------------------------------------------------------------------- #
class TreeEngine(DirectionEngine):
    """Materialized-tree jnp reference (the historical core.zo_grad path)."""

    name = "tree"

    def perturb(self, params, t, worker, scale):
        leaves = jax.tree.leaves(params)
        salts = self.salts(t, worker)
        vs = [self._gauss(i, s) for i, s in enumerate(salts)]  # materialized
        out = [
            (x.astype(jnp.float32) + scale * g).astype(x.dtype)
            for x, g in zip(leaves, vs)
        ]
        return jax.tree.unflatten(self.treedef, out)

    def _reconstruct(self, coeffs, t, workers):
        acc = self._acc_init()
        for i in range(int(coeffs.shape[0])):  # static unroll over workers
            w = _as_worker(workers[i])
            coeff = coeffs[i] * self.inv_norm(t, w)
            salts = self.salts(t, w)
            vs = [self._gauss(li, s) for li, s in enumerate(salts)]
            acc = [
                (a.astype(jnp.float32) + coeff * g).astype(self.acc_dtype)
                for a, g in zip(acc, vs)
            ]
        return jax.tree.unflatten(
            self.treedef, [a.astype(jnp.float32) for a in acc])


# --------------------------------------------------------------------------- #
class FusedEngine(DirectionEngine):
    """Fused-XLA formulation (the closures lifted out of make_zo_step).

    Generation is inlined into every consuming op, one leaf at a time, so
    XLA fuses the hash into the reduce/add/accumulate and the direction
    never exists as a program buffer; the worker loop is a ``fori_loop`` so
    the lowered HLO is O(1) in m.
    """

    name = "fused"

    def perturb(self, params, t, worker, scale):
        leaves = jax.tree.leaves(params)
        salts = self.salts(t, worker)
        out = [
            (x.astype(jnp.float32) + scale * self._gauss(i, s)).astype(x.dtype)
            for i, (x, s) in enumerate(zip(leaves, salts))
        ]
        return jax.tree.unflatten(self.treedef, out)

    def _reconstruct(self, coeffs, t, workers):
        def body(i, acc):
            w = _as_worker(workers[i])
            coeff = coeffs[i] * self.inv_norm(t, w)
            salts = self.salts(t, w)
            return [
                (a.astype(jnp.float32)
                 + coeff * self._gauss(li, s)).astype(self.acc_dtype)
                for li, (a, s) in enumerate(zip(acc, salts))
            ]

        acc = jax.lax.fori_loop(0, int(coeffs.shape[0]), body, self._acc_init())
        return jax.tree.unflatten(
            self.treedef, [a.astype(jnp.float32) for a in acc])


# --------------------------------------------------------------------------- #
class PallasEngine(DirectionEngine):
    """Pallas-kernel backend: the direction never touches HBM.

    ``perturb`` is one read + one write of x per leaf; ``reconstruct`` is a
    single pass over the parameters with all m gaussians generated in
    registers (``kernels.zo_direction``).  Leaves are processed flattened;
    arbitrary sizes are handled by the kernels' masked tail blocks.  The
    kernels run per-device (interpret mode on CPU, Mosaic on TPU) — use
    ``tree``/``fused`` for meshes where leaves are sharded across devices.
    """

    name = "pallas"

    def perturb(self, params, t, worker, scale):
        from repro.kernels import ops  # deferred: keeps core importable early

        leaves = jax.tree.leaves(params)
        salts = self.salts(t, worker)
        out = [
            self._constrain(
                ops.zo_perturb(x.reshape(-1), s, scale,
                               block=self.block).reshape(x.shape), i)
            for i, (x, s) in enumerate(zip(leaves, salts))
        ]
        return jax.tree.unflatten(self.treedef, out)

    def _reconstruct(self, coeffs, t, workers):
        from repro.kernels import ops

        m = int(coeffs.shape[0])
        invs = jnp.stack(
            [self.inv_norm(t, _as_worker(workers[i])) for i in range(m)])
        scaled = coeffs * invs
        per_leaf_salts = [
            jnp.stack([D.fold(self.seed, t, _as_worker(workers[i]), li)
                       for i in range(m)])
            for li in range(len(self.shapes))
        ]
        out = [
            self._constrain(
                ops.zo_reconstruct(self.sizes[li], per_leaf_salts[li], scaled,
                                   block=self.block,
                                   acc_dtype=str(self.acc_dtype)
                                   ).reshape(self.shapes[li]), li)
            for li in range(len(self.shapes))
        ]
        return jax.tree.unflatten(self.treedef, out)


# --------------------------------------------------------------------------- #
class FlatEngine(DirectionEngine):
    """Packed single-buffer backend: the whole tree in one Pallas launch.

    The parameter tree is packed once into a single contiguous f32 buffer
    with every leaf padded to a multiple of ``block``, so each grid block
    belongs to exactly one leaf; per-block ``(salt-index, counter-start,
    valid-lanes, is-bf16)`` metadata is precomputed at construction.  The
    hash identity is unchanged — leaf-local counters from 0, one salt per
    ``(t, worker, leaf)`` — so the algebra matches the other backends.

    * The standard primitives (``perturb``/``reconstruct``) pack, run ONE
      kernel for the whole tree (vs one per leaf in ``pallas``), and unpack;
      ``inv_norm`` stays the shared jnp reduction so the coefficients are
      bit-identical across backends by construction.
    * The fused step path (``pack``/``fused_perturb_sumsq``/
      ``fused_reconstruct_update``) keeps the buffer packed across the whole
      ZO round: the perturb pass accumulates the tree-wide ``sum(v^2)``
      in the same launch (no separate inv-norm pass over d), and the
      reconstruct pass applies the SGD(+momentum) update in-kernel with the
      params/momentum buffers donated and aliased in place — the update
      vector never exists in HBM.  The fused sumsq's blockwise reduction
      order differs from the jnp reduction, so the fused step is
      loss-equivalent (not bitwise) to the per-primitive path.

    Like ``pallas``, kernels run per-device (interpret on CPU, Mosaic on
    TPU) — use ``tree``/``fused`` for meshes where leaves are sharded.
    """

    name = "flat"

    def __init__(self, params_like: Any, seed: int, *, specs: Any = None,
                 acc_dtype: Any = "float32", block: int = 4096):
        super().__init__(params_like, seed, specs=specs, acc_dtype=acc_dtype,
                         block=block)
        blk_leaf, blk_ctr, blk_nv, blk_bf16 = [], [], [], []
        self.pad_offsets: List[int] = []   # leaf start in the PACKED buffer
        off = 0
        for i, n in enumerate(self.sizes):
            self.pad_offsets.append(off)
            nb = max(1, -(-n // block))    # scalars still occupy one block
            for b in range(nb):
                blk_leaf.append(i)
                blk_ctr.append(b * block)
                blk_nv.append(min(block, n - b * block))
            off += nb * block
        self.padded_dim = off
        self._blk_leaf = jnp.asarray(blk_leaf, jnp.int32)
        self._blk_ctr = jnp.asarray(blk_ctr, jnp.uint32)
        self._blk_nv = jnp.asarray(blk_nv, jnp.int32)
        self._blk_bf16 = jnp.asarray(
            [1 if self.dtypes[i] == jnp.bfloat16 else 0 for i in blk_leaf],
            jnp.int32)
        self.n_blocks = len(blk_leaf)

    # ---- packed-buffer layout ------------------------------------------- #
    def pack(self, tree: Any) -> jax.Array:
        """Tree -> (padded_dim,) contiguous f32 buffer (bf16 -> f32 exact)."""
        parts = []
        for i, x in enumerate(jax.tree.leaves(tree)):
            flat = x.astype(jnp.float32).reshape(-1)
            pad = -(-max(self.sizes[i], 1) // self.block) * self.block \
                - self.sizes[i]
            parts.append(jnp.pad(flat, (0, pad)) if pad else flat)
        return jnp.concatenate(parts)

    def unpack(self, buf: jax.Array, cast: bool = True) -> Any:
        """(padded_dim,) buffer -> tree; ``cast`` restores leaf dtypes
        (False returns fp32 leaves — update/momentum trees)."""
        outs = []
        for i, shape in enumerate(self.shapes):
            off = self.pad_offsets[i]
            leaf = buf[off:off + self.sizes[i]].reshape(shape)
            if cast:
                leaf = leaf.astype(self.dtypes[i])
            outs.append(self._constrain(leaf, i))
        return jax.tree.unflatten(self.treedef, outs)

    def blk_salts(self, t, worker) -> jax.Array:
        """(n_blocks,) uint32 — each block's leaf salt for (t, worker)."""
        return jnp.stack(self.salts(t, worker))[self._blk_leaf]

    def blk_salts_multi(self, t, workers) -> jax.Array:
        """(n_blocks, m) uint32 — per-(block, worker) salts."""
        m = int(workers.shape[0])
        return jnp.stack(
            [self.blk_salts(t, _as_worker(workers[i])) for i in range(m)],
            axis=1)

    # ---- standard primitives (pack -> one launch -> unpack) -------------- #
    def perturb(self, params, t, worker, scale):
        from repro.kernels import ops  # deferred: keeps core importable early

        out = ops.zo_perturb_flat(
            self.pack(params), self.blk_salts(t, worker), self._blk_ctr,
            self._blk_nv, scale, block=self.block)
        return self.unpack(out)

    def _reconstruct(self, coeffs, t, workers):
        from repro.kernels import ops

        m = int(coeffs.shape[0])
        invs = jnp.stack(
            [self.inv_norm(t, _as_worker(workers[i])) for i in range(m)])
        out = ops.zo_reconstruct_flat(
            self.blk_salts_multi(t, workers), coeffs * invs, self._blk_ctr,
            self._blk_nv, block=self.block, acc_dtype=str(self.acc_dtype))
        return self.unpack(out, cast=False)

    # ---- fused step path (buffer stays packed across the round) ---------- #
    def fused_perturb_sumsq(self, buf: jax.Array, t, worker, mu
                            ) -> Tuple[jax.Array, jax.Array]:
        """One launch: ``(buf + mu*rsqrt(sumsq)*v, sumsq)`` — the inv-norm
        pass over d disappears into the perturb's grid."""
        from repro.kernels import ops

        out, ss = ops.zo_perturb_sumsq(
            buf, self.blk_salts(t, worker), self._blk_ctr, self._blk_nv, mu,
            block=self.block)
        return out, ss[0]

    def fused_reconstruct_update(self, buf: jax.Array, mom, t, workers,
                                 scaled_coeffs: jax.Array, lr,
                                 momentum: float = 0.0):
        """One launch: regenerate all m directions in registers, contract
        with ``scaled_coeffs`` (= c_w * inv_norm_w * zo_scale / m), and
        commit the SGD(+momentum) update in place (donated buffers).

        Returns ``(buf', mom')``; ``mom'`` is None when ``mom`` is None.
        """
        from repro.kernels import ops

        return ops.zo_reconstruct_update(
            buf, mom, self.blk_salts_multi(t, workers), self._blk_ctr,
            self._blk_nv, self._blk_bf16, scaled_coeffs, lr,
            momentum=float(momentum), block=self.block,
            acc_dtype=str(self.acc_dtype))


# --------------------------------------------------------------------------- #
ENGINES = {
    "tree": TreeEngine,
    "fused": FusedEngine,
    "pallas": PallasEngine,
    "flat": FlatEngine,
}


def make_engine(name: str, params_like: Any, seed: int, *, specs: Any = None,
                acc_dtype: Any = "float32", block: int = 4096
                ) -> DirectionEngine:
    """Build a DirectionEngine backend by name
    ('tree' | 'fused' | 'pallas' | 'flat')."""
    try:
        cls = ENGINES[name]
    except KeyError:
        raise ValueError(
            f"unknown direction engine {name!r}; have {sorted(ENGINES)}"
        ) from None
    return cls(params_like, seed, specs=specs, acc_dtype=acc_dtype, block=block)
