"""Analytic reproductions of the paper's Theorem 1 bound and Table 1 rows.

These are the formulas the experiments are validated against:
``theorem1_bound`` is eq. (12) term by term; ``table1_row`` reproduces the
convergence-order / communication / computation columns for every method.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict


@dataclass(frozen=True)
class Problem:
    d: int            # model dimension
    m: int            # workers
    B: int            # batch size per worker
    N: int            # total iterations
    L: float = 1.0    # smoothness
    sigma: float = 1.0  # gradient-noise std bound
    f0_gap: float = 1.0  # f(x0) - f*


def min_iterations(p: Problem) -> int:
    """Theorem 1's validity condition N > 16 (d + Bm - 1)^2 / (Bm)."""
    return int(16 * (p.d + p.B * p.m - 1) ** 2 / (p.B * p.m)) + 1


def theorem_mu(p: Problem) -> float:
    """Smoothing parameter choice mu <= 1/sqrt(d N)."""
    return 1.0 / math.sqrt(p.d * p.N)


def theorem1_bound(p: Problem, tau: int) -> Dict[str, float]:
    """Eq. (12): every term of the average-squared-gradient-norm bound."""
    BmN = math.sqrt(p.B * p.m * p.N)
    terms = {
        "fo_descent": 4 * p.L * p.f0_gap / BmN,
        "fo_variance": 2 * p.sigma**2 / (BmN * tau),
    }
    if tau > 1:
        r = (tau - 1) / tau
        terms.update({
            "smooth_gap_1": 4 * p.L**2 / (p.d**2 * BmN * tau),
            "smooth_gap_2": 4 * p.L**2 / (p.d**2 * p.N * BmN),
            "zo_bias_1": p.L**2 / BmN * r,
            "zo_bias_2": p.L**2 / (p.N * BmN * tau),
            "zo_variance_1": 4 * p.d * p.sigma**2 / BmN * r,
            "zo_variance_2": 4 * p.d * p.sigma**2 / (p.N * BmN * tau),
            "zo_bias_3": p.L**2 / BmN * r,
            "zo_bias_4": p.L**2 / (p.N * BmN * tau),
        })
    terms["total"] = sum(v for k, v in terms.items() if k != "total")
    return terms


def convergence_order(p: Problem, tau: int) -> float:
    """Remark 1: O(d/sqrt(mN)) for tau>1, O(1/sqrt(mN)) for tau=1."""
    if tau > 1:
        return p.d / math.sqrt(p.m * p.N)
    return 1.0 / math.sqrt(p.m * p.N)


# --------------------------------------------------------------------------- #
# Table 1
# --------------------------------------------------------------------------- #
def table1_row(method: str, p: Problem, tau: int = 8, s: int = 4,
               mu_redundancy: float = 0.25, K_dataset: int = 50000) -> Dict[str, float]:
    """(convergence order, comm load/iter in scalars, normalized compute load).

    Compute load is normalized to the cost of one first-order stochastic
    gradient (the paper's convention; one ZO estimate = 2 function evals
    ~= (1/d) gradient-equivalents per Nesterov & Spokoiny 2017).
    """
    d, m, N = p.d, p.m, p.N
    rows = {
        "ho_sgd": dict(
            conv=d / math.sqrt(m * N) if tau > 1 else 1 / math.sqrt(m * N),
            comm=(tau - 1 + d) / tau,
            comp=1 / tau + 1 / d,
        ),
        "ri_sgd": dict(
            conv=tau / math.sqrt(m * N),
            comm=d / tau,
            comp=mu_redundancy * m + 1,
        ),
        "sync_sgd": dict(conv=1 / math.sqrt(m * N), comm=float(d), comp=1.0),
        "zo_sgd": dict(
            conv=(d / m) ** (1 / 3) / N ** (1 / 4), comm=1.0, comp=1 / d
        ),
        "zo_svrg_ave": dict(
            conv=d / N + 1 / min(d, m), comm=1.0, comp=K_dataset / d
        ),
        "qsgd": dict(
            conv=1 / N + math.sqrt(d),
            comm=(s**2 + s * math.sqrt(d)) / 32.0,
            comp=1.5,
        ),
    }
    if method not in rows:
        raise KeyError(method)
    return rows[method]
