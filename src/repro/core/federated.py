"""Federated partial participation for the round IR (README §RoundProgram).

The simulator tops out at tens of always-on workers; federated regimes are
N ≫ m client populations where each round samples a small cohort.  This
module adds exactly the missing piece — *who participates* — on top of the
existing ``repro.core.rounds`` machinery:

  * ``ClientSampling(n_clients, cohort_k, seed, availability)`` is a frozen
    spec attached to a ``RoundProgram``.  Every round draws a seeded cohort
    of K of N client ids without replacement, then applies per-client
    availability churn (a seeded Bernoulli dropout mask over the drawn
    cohort, at least one survivor).  Same spec + same ``t`` ⇒ the same
    cohort, bit for bit — the sim's determinism contract extends to
    membership.
  * Each sampled client computes its round ``local`` on its OWN data shard:
    ``cohort_shards`` draws client c's rows from the global batch with an
    rng keyed on the client's IDENTITY (and ``t``), never its position in
    the cohort — a client's data stream is invariant to who else was
    sampled, matching how ``Wire``/ZO-direction streams are keyed.
  * ``fed_avg_program`` builds the two averaging baselines of the federated
    frontier as ordinary round programs committing through the
    ``masked_average`` collective (``rounds.masked_average``): FedAvg-style
    local-update averaging (``dropout=0``) and FedDropoutAvg-style masked
    averaging (each client zeroes a seeded fraction of its payload; the
    server averages per coordinate over the clients that actually sent it,
    weighted by nonzero-mask × client dataset size).

HO-SGD itself goes federated by passing ``client_sampling=`` to
``rounds.ho_sgd_program``: the cohort's FO gradients all-reduce, the
cohort's ZO coefficients all-gather, and the pre-shared direction streams
survive sampling because they were always keyed on worker IDENTITY.

Wire accounting: a ``masked_average`` round books per-client payload bytes
× |live cohort| — what the sampled clients actually upload, never × N —
through the one wire model in ``rounds.wire_nbytes``; codecs (qsgd/topk)
compose per client.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rounds as R

#: namespace salt so federated draws never collide with other np seed uses
_FED_SALT = 0x0FED


@dataclass(frozen=True)
class ClientSampling:
    """K-of-N partial participation: the seeded cohort schedule.

    ``cohort_for(t)`` draws the round-``t`` cohort: ``cohort_k`` of
    ``n_clients`` ids without replacement, then an independent
    per-client availability draw (probability ``availability`` of showing
    up; at least one survivor — an all-down round re-admits a seeded pick).
    Ids come back sorted ascending, matching the runner's membership
    convention.

    ``client_sizes()`` is the per-client dataset-size vector (seeded
    lognormal counts ≥ 1, fixed per spec) — the masked-average weights.
    """

    n_clients: int
    cohort_k: int
    seed: int = 0
    availability: float = 1.0

    def __post_init__(self):
        assert self.n_clients >= 1
        assert 1 <= self.cohort_k <= self.n_clients, \
            f"cohort_k={self.cohort_k} not in [1, n_clients={self.n_clients}]"
        assert 0.0 < self.availability <= 1.0, \
            f"availability must be in (0, 1], got {self.availability}"

    def _rng(self, *salt: int) -> np.random.Generator:
        return np.random.default_rng([_FED_SALT, self.seed, *salt])

    def cohort_for(self, t: int) -> Tuple[int, ...]:
        """Sorted client ids participating in round ``t`` (live cohort)."""
        rng = self._rng(1, int(t))
        ids = rng.choice(self.n_clients, size=self.cohort_k, replace=False)
        if self.availability < 1.0:
            up = rng.random(self.cohort_k) < self.availability
            if not up.any():
                up[int(rng.integers(self.cohort_k))] = True
            ids = ids[up]
        return tuple(int(i) for i in np.sort(ids))

    def client_sizes(self) -> np.ndarray:
        """(n_clients,) int64 dataset sizes — seeded once per spec."""
        rng = self._rng(2)
        raw = rng.lognormal(mean=4.0, sigma=0.75, size=self.n_clients)
        return np.maximum(1, np.round(raw)).astype(np.int64)

    def client_weights(self, cohort: Sequence[int]) -> np.ndarray:
        """Masked-average weights of a cohort: each client's dataset size."""
        sizes = self.client_sizes()
        return sizes[np.asarray(list(cohort), dtype=np.int64)].astype(
            np.float64)


def cohort_shards(batch: Any, cohort: Sequence[int], t: int,
                  cs: ClientSampling) -> Any:
    """Stack each sampled client's OWN shard of the global batch.

    Client c's rows are drawn by an rng keyed on (spec seed, c, t) — the
    client's identity, so its data stream is invariant to who else was
    sampled (and to availability churn).  Every client gets
    ``n_rows // cohort_k`` rows (the same per-worker batch the always-on
    replay would shard), stacked on a new leading cohort axis.
    """
    leaves = jax.tree.leaves(batch)
    n = int(leaves[0].shape[0])
    per = n // cs.cohort_k
    assert per >= 1, f"batch of {n} rows cannot feed cohorts of {cs.cohort_k}"
    rows = np.stack([
        np.random.default_rng([_FED_SALT, cs.seed, 3, int(c), int(t)])
        .choice(n, size=per, replace=False)
        for c in cohort])
    return jax.tree.map(lambda x: x[rows], batch)


# --------------------------------------------------------------------------- #
# FedAvg / FedDropoutAvg as round programs
# --------------------------------------------------------------------------- #
def fed_avg_round(loss_fn: Callable, *, lr: float, local_steps: int,
                  dropout: float = 0.0, seed: int = 0,
                  wire: Optional[R.Wire] = None, tag: str = "fed_avg",
                  ) -> R.Round:
    """One communication round of FedAvg / FedDropoutAvg.

    ``local``: each client runs ``local_steps`` SGD steps over equal
    micro-slices of its shard and uploads the resulting model tree.  With
    ``dropout > 0`` (FedDropoutAvg) the client zeroes a seeded fraction of
    every uploaded leaf — keys folded on (t, client id), so a client's
    dropout mask is invariant to the rest of the cohort.

    ``apply``: the ``masked_average`` collective hands over ``(avg, wsum)``;
    coordinates no surviving client sent (``wsum == 0``) keep the server's
    old value.
    """
    wire = wire or R.Wire()
    drop = float(dropout)
    assert 0.0 <= drop < 1.0, f"dropout must be in [0, 1), got {drop}"

    def local(t, worker, model, shard):
        n = jax.tree.leaves(shard)[0].shape[0]
        assert n % local_steps == 0, \
            f"client shard of {n} rows cannot split into {local_steps} steps"
        micro = jax.tree.map(
            lambda x: x.reshape((local_steps, n // local_steps)
                                + x.shape[1:]), shard)

        def body(p, mb):
            loss, g = jax.value_and_grad(loss_fn)(p, mb)
            p = jax.tree.map(
                lambda a, b: (a.astype(jnp.float32)
                              - lr * b.astype(jnp.float32)).astype(a.dtype),
                p, g)
            return p, loss

        out, losses = jax.lax.scan(body, model, micro)
        if drop > 0.0:
            key = jax.random.fold_in(
                jax.random.fold_in(jax.random.key(seed), t), worker)
            leaves, treedef = jax.tree.flatten(out)
            keys = jax.random.split(key, len(leaves))
            leaves = [jnp.where(jax.random.bernoulli(k, 1.0 - drop, x.shape),
                                x, jnp.zeros_like(x))
                      for k, x in zip(keys, leaves)]
            out = jax.tree.unflatten(treedef, leaves)
        return out, jnp.mean(losses)

    @jax.jit
    def _apply_j(params, avg, wsum, f_mean):
        params = jax.tree.map(
            lambda p, a, s: jnp.where(s > 0, a.astype(p.dtype), p),
            params, avg, wsum)
        return params, f_mean

    def apply(t, params, state, reduced, workers, aux):
        avg, wsum = reduced
        params, loss = _apply_j(params, avg, wsum, jnp.mean(aux))
        return params, state, {"loss": loss}

    return R.Round(tag, 1, "masked_average", local, apply, wire=wire,
                   meta={"loss_fn": loss_fn, "lr": lr,
                         "local_steps": local_steps, "dropout": drop})


def fed_avg_program(loss_fn: Callable, sampling: ClientSampling, *,
                    lr: float, local_steps: int = 4, dropout: float = 0.0,
                    seed: int = 0, wire: Optional[R.Wire] = None,
                    name: str = "fed_avg") -> R.RoundProgram:
    """FedAvg (``dropout=0``) / FedDropoutAvg as a ``RoundProgram``.

    Every round is the same ``masked_average`` round over a freshly sampled
    cohort (``sampling``); ``m = cohort_k`` — the program's worker slots ARE
    the cohort.  Analytic Table-1 hooks: each round uploads |cohort| model
    trees (``comm_scalars``) and costs ``local_steps`` gradient evals per
    client.
    """
    rnd = fed_avg_round(loss_fn, lr=lr, local_steps=local_steps,
                        dropout=dropout, seed=seed, wire=wire, tag=name)

    def init(params):
        return {}

    def round_for(t: int, state) -> R.RoundStep:
        return R.RoundStep(rnd, t, {})

    return R.RoundProgram(
        name, sampling.cohort_k, init, round_for,
        comm_scalars=lambda d: float(sampling.cohort_k) * d,
        fevals=lambda d: 0.0,
        gevals=lambda d: float(local_steps),
        client_sampling=sampling,
    )
