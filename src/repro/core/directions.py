"""Pre-shared-seed random direction generation (the paper's §3 trick).

Directions are a *pure function* of ``(seed, iteration, worker, leaf,
element-position)`` through a counter-based integer hash, so every worker can
regenerate every other worker's direction without communicating any vector —
only the pre-shared integer ``seed`` is exchanged once, before optimization.

The same hash is implemented three times, bit-identically:
  * here (pure jnp)            — reference + distributed optimizer,
  * kernels/zo_direction.py    — Pallas TPU kernel (on-the-fly, never in HBM),
  * kernels/ref.py             — oracle used by the kernel tests.

Being elementwise in the *global* flat index, generation is consistent under
any XLA sharding of the parameter leaf (iota is partitioned correctly).
"""
from __future__ import annotations

from typing import Any, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Plain Python ints (not jnp arrays) so Pallas kernels can use these without
# capturing traced constants; uint32 arithmetic wraps mod 2**32 as intended.
_M1 = np.uint32(0x7FEB352D)
_M2 = np.uint32(0x846CA68B)
_GOLDEN = np.uint32(0x9E3779B9)
_SALT2 = np.uint32(0x85EBCA6B)
_XOR2 = np.uint32(0xC2B2AE35)
_TWO_PI = 6.283185307179586


def mix32(x: jax.Array) -> jax.Array:
    """Full-avalanche 32-bit integer hash (lowbias32)."""
    x = jnp.asarray(x, jnp.uint32)
    x = x ^ (x >> 16)
    x = x * _M1
    x = x ^ (x >> 15)
    x = x * _M2
    x = x ^ (x >> 16)
    return x


def fold(*ints) -> jax.Array:
    """Combine integers into one uint32 salt (order-sensitive)."""
    acc = jnp.zeros((), jnp.uint32)
    for v in ints:
        acc = mix32(acc ^ (jnp.asarray(v, jnp.uint32) * _GOLDEN))
    return acc


def _uniform01(bits: jax.Array) -> jax.Array:
    """uint32 -> float32 in (0, 1): top 24 bits, never exactly 0."""
    return (bits >> 8).astype(jnp.float32) * jnp.float32(2**-24) + jnp.float32(2**-25)


def gaussian_from_salt(shape: Tuple[int, ...], salt: jax.Array,
                       offset: jax.Array | int = 0) -> jax.Array:
    """Standard-normal array from a counter hash (Box–Muller, cos branch).

    ``offset`` shifts the flat counter so one leaf's elements can be split
    across calls (used by the Pallas kernel's grid blocks and its oracle).

    The row-major flat index is built from per-dim ``broadcasted_iota``
    (NOT a flat 1-D iota + reshape): elementwise iotas partition trivially
    under any sharding, whereas the flat-iota form makes the SPMD
    partitioner materialize the whole leaf replicated per device before
    resharding — catastrophic for billion-parameter leaves.
    """
    if len(shape) == 0:
        idx = jnp.asarray(offset, jnp.uint32).reshape(())
    else:
        # the counter wraps mod 2**32: leaves with > 4.3e9 elements (arctic's
        # expert stack) repeat gaussian values every 2**32 positions — a
        # negligible, documented correlation (the Pallas kernels' uint32
        # arithmetic wraps identically, keeping all three paths bit-equal)
        idx = jnp.zeros(shape, jnp.uint32)
        stride = 1
        for d in range(len(shape) - 1, -1, -1):
            if shape[d] > 1:
                idx = idx + jax.lax.broadcasted_iota(jnp.uint32, shape, d) * np.uint32(stride & 0xFFFFFFFF)
            stride *= int(shape[d])
        idx = idx + jnp.asarray(offset, jnp.uint32)
    h1 = mix32(idx * _GOLDEN + salt)
    h2 = mix32(idx * _SALT2 + (salt ^ _XOR2))
    u1 = _uniform01(h1)
    u2 = _uniform01(h2)
    return jnp.sqrt(-2.0 * jnp.log(u1)) * jnp.cos(_TWO_PI * u2)


# --------------------------------------------------------------------------- #
# whole-parameter-tree directions
# --------------------------------------------------------------------------- #
def tree_dim(params: Any) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


def leaf_salts(params: Any, seed: int, t: jax.Array, worker: jax.Array) -> List[jax.Array]:
    leaves = jax.tree.leaves(params)
    return [fold(seed, t, worker, i) for i in range(len(leaves))]


def raw_direction(params: Any, seed: int, t, worker) -> Any:
    """Unnormalized Gaussian direction tree, same structure as ``params``."""
    leaves, treedef = jax.tree.flatten(params)
    salts = [fold(seed, t, worker, i) for i in range(len(leaves))]
    vs = [gaussian_from_salt(x.shape, s) for x, s in zip(leaves, salts)]
    return jax.tree.unflatten(treedef, vs)


def sphere_direction(params: Any, seed: int, t, worker) -> Any:
    """Uniform-on-the-unit-sphere direction over the whole d-dim tree.

    The norm is a *global* reduction across leaves; under model-axis sharding
    XLA realizes it as per-shard partial sums + one scalar all-reduce — still
    O(1) communication, as required by the paper's cost accounting.
    """
    v = raw_direction(params, seed, t, worker)
    sumsq = sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(v))
    inv = jax.lax.rsqrt(sumsq + 1e-30)
    return jax.tree.map(lambda x: x * inv, v)


def tree_axpy(a, x_tree, y_tree):
    """y + a*x, cast back to y's dtypes (params stay in their own dtype)."""
    return jax.tree.map(
        lambda x, y: (y.astype(jnp.float32) + a * x.astype(jnp.float32)).astype(y.dtype),
        x_tree,
        y_tree,
    )
