"""Baselines the paper compares against (Table 1 / §5).

* PA-SGD — periodic model averaging (McMahan et al. 2016; Wang & Joshi 2018):
  each worker runs local SGD, models averaged every tau iterations.
* RI-SGD — redundancy-infused model averaging (Haddadpour et al. 2019):
  PA-SGD where each worker's shard overlaps a mu_r fraction of its peers'
  data (emulated at the data layer via ``ri_shard_batch``).
* ZO-SVRG-Ave — zeroth-order SVRG (Liu et al. 2018): epoch anchor gradient
  over the full dataset + variance-reduced ZO inner steps.  Requires full
  dataset storage (the drawback the paper highlights).
* QSGD — s-level stochastically-quantized gradient SGD (Alistarh et al. 2017).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import directions as D
from repro.core.ho_sgd import Method, _split_workers
from repro.core.zo_grad import zo_coefficient
from repro.opt.optimizers import apply_deltas


# --------------------------------------------------------------------------- #
# PA-SGD / RI-SGD (model averaging)
# --------------------------------------------------------------------------- #
def make_pa_sgd(loss_fn, m: int, tau: int, lr: float, name: str = "pa_sgd") -> Method:
    @jax.jit
    def local_steps(params_m, batch_m):
        """One local SGD step per worker (vmapped over the worker dim)."""
        def one(params, batch):
            loss, g = jax.value_and_grad(loss_fn)(params, batch)
            params = jax.tree.map(
                lambda p, gg: (p.astype(jnp.float32) - lr * gg.astype(jnp.float32)).astype(p.dtype),
                params, g)
            return params, loss
        return jax.vmap(one)(params_m, batch_m)

    @jax.jit
    def average(params_m):
        avg = jax.tree.map(lambda x: jnp.mean(x.astype(jnp.float32), 0), params_m)
        return jax.tree.map(
            lambda x, a: jnp.broadcast_to(a.astype(x.dtype), x.shape), params_m, avg)

    def init(params):
        return jax.tree.map(lambda p: jnp.broadcast_to(p[None], (m, *p.shape)), params)

    def step(t, params, params_m, batch, key=None):
        # ``params`` tracks the averaged model; local replicas live in state.
        batch_m = _split_workers(batch, m)
        params_m, losses = local_steps(params_m, batch_m)
        synced = (t + 1) % tau == 0
        if synced:
            params_m = average(params_m)
        params = jax.tree.map(lambda x: x[0], params_m)
        return params, params_m, {"loss": jnp.mean(losses), "order": 1}

    return Method(
        name, init, step,
        comm_scalars=lambda d: d / tau,
        fevals=lambda d: 0.0,
        gevals=lambda d: 1.0,
    )


def ri_shard_batch(batch: Any, m: int, mu_r: float, key) -> Any:
    """Emulate RI-SGD's redundancy: replace a mu_r fraction of each worker's
    shard with samples drawn from the other workers' shards."""
    def mix(x):
        mB = x.shape[0]
        B = mB // m
        n_red = int(round(mu_r * B))
        if n_red == 0:
            return x
        xs = x.reshape(m, B, *x.shape[1:])
        idx = jax.random.randint(key, (m, n_red), 0, mB)
        foreign = x[idx]  # (m, n_red, ...)
        return jnp.concatenate([xs[:, : B - n_red], foreign], axis=1).reshape(x.shape)
    return jax.tree.map(mix, batch)


def make_ri_sgd(loss_fn, m: int, tau: int, lr: float, mu_r: float = 0.25) -> Method:
    base = make_pa_sgd(loss_fn, m, tau, lr, name="ri_sgd")

    def step(t, params, state, batch, key=None):
        key = key if key is not None else jax.random.key(t)
        batch = ri_shard_batch(batch, m, mu_r, jax.random.fold_in(key, t))
        return base.step(t, params, state, batch)

    # RI-SGD stores (1 + mu_r*m) shards per worker -> higher compute/storage
    return base._replace(
        step=step, gevals=lambda d: 1.0 + mu_r,  # extra redundant-sample grads
    )


# --------------------------------------------------------------------------- #
# ZO-SVRG-Ave (Liu et al., 2018)
# --------------------------------------------------------------------------- #
def make_zo_svrg_ave(
    loss_fn, m: int, mu: float, lr: float, dataset: Any,
    epoch_len: int = 50, seed: int = 0,
) -> Method:
    """RandGradEst averaged over m directions; anchor refreshed per epoch."""

    def zo_est(params, batch, t, salt):
        dim = D.tree_dim(params)
        acc = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        f0_keep = jnp.float32(0.0)
        for i in range(m):
            v = D.sphere_direction(params, seed + salt, t, jnp.uint32(i))
            c, f0 = zo_coefficient(loss_fn, params, batch, v, mu, dim)
            acc = jax.tree.map(lambda a, x: a + c * x.astype(jnp.float32), acc, v)
            f0_keep = f0
        return jax.tree.map(lambda a: a / m, acc), f0_keep

    @jax.jit
    def anchor_grad(params, t):
        return zo_est(params, dataset, t, salt=7)

    @jax.jit
    def inner(t, params, anchor_params, g_anchor, batch):
        g_t, f0 = zo_est(params, batch, t, salt=0)
        g_a, _ = zo_est(anchor_params, batch, t, salt=0)   # same directions
        vr = jax.tree.map(lambda a, b, c: a - b + c, g_t, g_a, g_anchor)
        params = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32) - lr * g).astype(p.dtype), params, vr)
        return params, f0

    def init(params):
        g_anchor, _ = anchor_grad(params, jnp.int32(0))
        return {"anchor": params, "g_anchor": g_anchor}

    def step(t, params, state, batch, key=None):
        if t % epoch_len == 0 and t > 0:
            g_anchor, _ = anchor_grad(params, jnp.int32(t))
            state = {"anchor": params, "g_anchor": g_anchor}
        params, f0 = inner(jnp.int32(t), params, state["anchor"],
                           state["g_anchor"], batch)
        return params, state, {"loss": f0, "order": 0}

    K = epoch_len
    return Method(
        "zo_svrg_ave", init, step,
        comm_scalars=lambda d: 1.0,
        fevals=lambda d: 4.0 + 2.0 / K,   # two estimates/step + anchor amortized
        gevals=lambda d: 0.0,
    )


# --------------------------------------------------------------------------- #
# QSGD (Alistarh et al., 2017)
# --------------------------------------------------------------------------- #
def quantize_qsgd(g: jax.Array, s: int, key) -> jax.Array:
    """Unbiased s-level stochastic quantization Q_s(g) of one flat vector."""
    norm = jnp.linalg.norm(g) + 1e-30
    level = jnp.abs(g) / norm * s
    lower = jnp.floor(level)
    prob = level - lower
    bump = jax.random.bernoulli(key, prob).astype(jnp.float32)
    return jnp.sign(g) * norm * (lower + bump) / s


def make_qsgd(loss_fn, m: int, s: int, lr: float) -> Method:
    @jax.jit
    def step_jit(t, params, batch_m, key):
        def worker_grad(params, batch):
            return jax.value_and_grad(loss_fn)(params, batch)
        losses, grads_m = jax.vmap(worker_grad, in_axes=(None, 0))(params, batch_m)
        leaves, treedef = jax.tree.flatten(grads_m)
        keys = jax.random.split(key, len(leaves) * m).reshape(len(leaves), m)
        q = [
            jax.vmap(lambda gw, kk: quantize_qsgd(gw.reshape(-1), s, kk).reshape(gw.shape))(
                lf, keys[j]
            )
            for j, lf in enumerate(leaves)
        ]
        g_mean = jax.tree.map(
            lambda x: jnp.mean(x.astype(jnp.float32), 0), jax.tree.unflatten(treedef, q))
        params = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32) - lr * g).astype(p.dtype), params, g_mean)
        return params, jnp.mean(losses)

    def init(params):
        return ()

    def step(t, params, state, batch, key=None):
        key = key if key is not None else jax.random.key(0)
        batch_m = _split_workers(batch, m)
        params, loss = step_jit(jnp.int32(t), params, batch_m, jax.random.fold_in(key, t))
        return params, state, {"loss": loss, "order": 1}

    import math
    return Method(
        "qsgd", init, step,
        comm_scalars=lambda d: (s * s + s * math.sqrt(d)) / 32.0,  # ~bits/32 per Table 1
        fevals=lambda d: 0.0,
        gevals=lambda d: 1.0,
    )
