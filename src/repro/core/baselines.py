"""Baselines the paper compares against (Table 1 / §5), as round programs.

* PA-SGD — periodic model averaging (McMahan et al. 2016; Wang & Joshi 2018):
  each worker runs local SGD, models averaged every tau iterations.
* RI-SGD — redundancy-infused model averaging (Haddadpour et al. 2019):
  PA-SGD where each worker's shard overlaps a mu_r fraction of its peers'
  data (emulated at the data layer via ``ri_shard_batch``).
* Gossip-PA — decentralized PA-SGD: the averaging round is a ring
  ``neighbor_exchange`` (each worker mixes with its two ring neighbors)
  instead of a full ``tree_average`` — the decentralized scenario the
  round IR opens (cf. the compressed-ZO decentralized baselines in
  PAPERS.md).
* ZO-SVRG-Ave — zeroth-order SVRG (Liu et al. 2018): epoch anchor gradient
  over the full dataset + variance-reduced ZO inner steps.  Requires full
  dataset storage (the drawback the paper highlights).  Not a per-round
  collective method — stays a plain ``Method``.
* QSGD — s-level stochastically-quantized gradient SGD (Alistarh et al.
  2017), expressed through the round IR's wire codec hook: every worker
  encodes its own shard gradient (``repro.dist.compress.qsgd``), the
  reducer decodes — per-worker wire bytes = ``nbytes`` × active workers.

PA/RI/Gossip/QSGD are ``repro.core.rounds`` programs; their ``Method`` view
(``rounds.to_method``) runs the schedule over all m workers single-host,
and the simulator replays the same programs per worker
(``Method.program``).
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import directions as D
from repro.core import rounds as R
from repro.core.ho_sgd import Method
from repro.core.zo_grad import zo_coefficient
from repro.dist import compress as compress_mod


# --------------------------------------------------------------------------- #
# PA-SGD / RI-SGD / Gossip-PA (model averaging as round programs)
# --------------------------------------------------------------------------- #
def _pa_rounds(loss_fn, lr: float):
    """The two PA-SGD rounds: a local-SGD round (no collective) and an
    averaging round; both run the same per-replica SGD local."""

    def local(t, worker, model, shard):
        loss, g = jax.value_and_grad(loss_fn)(model, shard)
        new = jax.tree.map(
            lambda p, gg: (p.astype(jnp.float32)
                           - lr * gg.astype(jnp.float32)).astype(p.dtype),
            model, g)
        return new, loss

    @jax.jit
    def _write_back(replicas, updated, workers):
        return jax.tree.map(lambda Rr, U: Rr.at[workers].set(U),
                            replicas, updated)

    @jax.jit
    def _broadcast_avg(replicas, avg, workers):
        return jax.tree.map(
            lambda Rr, A: Rr.at[workers].set(
                jnp.broadcast_to(A.astype(Rr.dtype),
                                 (workers.shape[0], *A.shape))),
            replicas, avg)

    def apply_local(t, params, state, reduced, workers, aux):
        replicas = _write_back(state["replicas"], reduced, workers)
        params = jax.tree.map(lambda x: x[0], replicas)
        return params, {**state, "replicas": replicas}, {
            "loss": jnp.mean(aux)}

    def apply_avg(t, params, state, reduced, workers, aux):
        replicas = _broadcast_avg(state["replicas"], reduced, workers)
        params = jax.tree.map(lambda x: x[0], replicas)
        return params, {**state, "replicas": replicas}, {
            "loss": jnp.mean(aux)}

    def apply_mix(t, params, state, reduced, workers, aux):
        # neighbor_exchange: reduced is worker-stacked mixed replicas
        replicas = _write_back(state["replicas"], reduced, workers)
        params = jax.tree.map(lambda x: x[0], replicas)
        return params, {**state, "replicas": replicas}, {
            "loss": jnp.mean(aux)}

    step_rnd = R.Round("pa_local", 1, "none", local, apply_local,
                       replica=True)
    avg_rnd = R.Round("pa_avg", 1, "tree_average", local, apply_avg,
                      replica=True)
    mix_rnd = R.Round("pa_gossip", 1, "neighbor_exchange", local, apply_mix,
                      replica=True)
    return step_rnd, avg_rnd, mix_rnd


def pa_sgd_program(loss_fn, m: int, tau: int, lr: float, *,
                   name: str = "pa_sgd", gossip: bool = False,
                   prepare=None, gevals: float = 1.0) -> R.RoundProgram:
    step_rnd, avg_rnd, mix_rnd = _pa_rounds(loss_fn, lr)
    sync_rnd = mix_rnd if gossip else avg_rnd

    def init(params):
        return {"replicas": jax.tree.map(
            lambda p: jnp.broadcast_to(p[None], (m, *p.shape)), params)}

    def round_for(t: int, state) -> R.RoundStep:
        synced = (t + 1) % tau == 0
        return R.RoundStep(sync_rnd if synced else step_rnd, t, {})

    # gossip moves min(2, m-1) neighbor models per averaging round instead
    # of the one averaged tree of the all-to-all exchange
    per_sync = float(min(2, m - 1)) if gossip else 1.0
    return R.RoundProgram(
        name, m, init, round_for,
        comm_scalars=lambda d: per_sync * d / tau,
        fevals=lambda d: 0.0,
        gevals=lambda d: gevals,
        prepare=prepare,
    )


def make_pa_sgd(loss_fn, m: int, tau: int, lr: float,
                name: str = "pa_sgd") -> Method:
    return R.to_method(pa_sgd_program(loss_fn, m, tau, lr, name=name))


def make_gossip_pa_sgd(loss_fn, m: int, tau: int, lr: float) -> Method:
    """Decentralized PA-SGD: ring-gossip mixing on the averaging rounds."""
    return R.to_method(pa_sgd_program(loss_fn, m, tau, lr, name="pa_gossip",
                                      gossip=True))


def ri_shard_batch(batch: Any, m: int, mu_r: float, key) -> Any:
    """Emulate RI-SGD's redundancy: replace a mu_r fraction of each worker's
    shard with samples drawn from the other workers' shards."""
    def mix(x):
        mB = x.shape[0]
        B = mB // m
        n_red = int(round(mu_r * B))
        if n_red == 0:
            return x
        xs = x.reshape(m, B, *x.shape[1:])
        idx = jax.random.randint(key, (m, n_red), 0, mB)
        foreign = x[idx]  # (m, n_red, ...)
        return jnp.concatenate([xs[:, : B - n_red], foreign], axis=1).reshape(x.shape)
    return jax.tree.map(mix, batch)


def make_ri_sgd(loss_fn, m: int, tau: int, lr: float, mu_r: float = 0.25) -> Method:
    def prepare(t, batch, key):
        key = key if key is not None else jax.random.key(t)
        return ri_shard_batch(batch, m, mu_r, jax.random.fold_in(key, t))

    # RI-SGD stores (1 + mu_r*m) shards per worker -> higher compute/storage
    return R.to_method(pa_sgd_program(loss_fn, m, tau, lr, name="ri_sgd",
                                      prepare=prepare, gevals=1.0 + mu_r))


# --------------------------------------------------------------------------- #
# ZO-SVRG-Ave (Liu et al., 2018)
# --------------------------------------------------------------------------- #
def make_zo_svrg_ave(
    loss_fn, m: int, mu: float, lr: float, dataset: Any,
    epoch_len: int = 50, seed: int = 0,
) -> Method:
    """RandGradEst averaged over m directions; anchor refreshed per epoch."""

    def zo_est(params, batch, t, salt):
        dim = D.tree_dim(params)
        acc = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        f0_keep = jnp.float32(0.0)
        for i in range(m):
            v = D.sphere_direction(params, seed + salt, t, jnp.uint32(i))
            c, f0 = zo_coefficient(loss_fn, params, batch, v, mu, dim)
            acc = jax.tree.map(lambda a, x: a + c * x.astype(jnp.float32), acc, v)
            f0_keep = f0
        return jax.tree.map(lambda a: a / m, acc), f0_keep

    @jax.jit
    def anchor_grad(params, t):
        return zo_est(params, dataset, t, salt=7)

    @jax.jit
    def inner(t, params, anchor_params, g_anchor, batch):
        g_t, f0 = zo_est(params, batch, t, salt=0)
        g_a, _ = zo_est(anchor_params, batch, t, salt=0)   # same directions
        vr = jax.tree.map(lambda a, b, c: a - b + c, g_t, g_a, g_anchor)
        params = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32) - lr * g).astype(p.dtype), params, vr)
        return params, f0

    def init(params):
        g_anchor, _ = anchor_grad(params, jnp.int32(0))
        return {"anchor": params, "g_anchor": g_anchor}

    def step(t, params, state, batch, key=None):
        if t % epoch_len == 0 and t > 0:
            g_anchor, _ = anchor_grad(params, jnp.int32(t))
            state = {"anchor": params, "g_anchor": g_anchor}
        params, f0 = inner(jnp.int32(t), params, state["anchor"],
                           state["g_anchor"], batch)
        return params, state, {"loss": f0, "order": 0}

    K = epoch_len
    return Method(
        "zo_svrg_ave", init, step,
        comm_scalars=lambda d: 1.0,
        fevals=lambda d: 4.0 + 2.0 / K,   # two estimates/step + anchor amortized
        gevals=lambda d: 0.0,
    )


# --------------------------------------------------------------------------- #
# QSGD (Alistarh et al., 2017) — through the round IR's wire codec hook
# --------------------------------------------------------------------------- #
def quantize_qsgd(g: jax.Array, s: int, key) -> jax.Array:
    """Unbiased s-level stochastic quantization Q_s(g) of one flat vector
    (the reference quantizer; the QSGD method itself rides the
    ``repro.dist.compress.qsgd`` codec through the round IR's wire hook)."""
    norm = jnp.linalg.norm(g) + 1e-30
    level = jnp.abs(g) / norm * s
    lower = jnp.floor(level)
    prob = level - lower
    bump = jax.random.bernoulli(key, prob).astype(jnp.float32)
    return jnp.sign(g) * norm * (lower + bump) / s


def qsgd_program(loss_fn, m: int, s: int, lr: float, *,
                 compress_mode: str = "per_worker") -> R.RoundProgram:
    codec = compress_mod.qsgd(s)

    def local(t, worker, model, shard):
        loss, g = jax.value_and_grad(loss_fn)(model, shard)
        return g, loss

    @jax.jit
    def _apply_j(t, params, g_mean, f_mean):
        params = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32)
                          - lr * g.astype(jnp.float32)).astype(p.dtype),
            params, g_mean)
        return params, f_mean

    def apply(t, params, state, reduced, workers, aux):
        params, loss = _apply_j(t, params, reduced, jnp.mean(aux))
        return params, state, {"loss": loss}

    rnd = R.Round("qsgd", 1, "all_reduce", local, apply,
                  wire=R.Wire(codec, compress_mode))

    def init(params):
        return {}

    def round_for(t: int, state) -> R.RoundStep:
        return R.RoundStep(rnd, t, {})

    return R.RoundProgram(
        "qsgd", m, init, round_for,
        comm_scalars=lambda d: (s * s + s * math.sqrt(d)) / 32.0,  # Table 1
        fevals=lambda d: 0.0,
        gevals=lambda d: 1.0,
    )


def make_qsgd(loss_fn, m: int, s: int, lr: float,
              compress_mode: str = "per_worker") -> Method:
    return R.to_method(qsgd_program(loss_fn, m, s, lr,
                                    compress_mode=compress_mode))
