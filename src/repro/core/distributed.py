"""Mesh-distributed HO-SGD: the production lowering of the round-program IR.

The method itself — per-worker rounds with an FO gradient sync every tau
iterations — is defined ONCE in ``repro.core.rounds`` (``fo_round`` /
``zo_round`` / ``ho_sgd_program``).  This module LOWERS those rounds to a
device mesh, fusing each round's per-worker locals + collective + apply
into one jitted program:

* ``make_fo_step``  — lowers the FO round (eq. 3): pjit data-parallel step
  whose d-dimensional gradient all-reduce over the worker axes is inserted
  by XLA (this is the expensive collective the paper amortizes over tau).
  The round's wire codec lowers to a per-worker encode + reducer decode
  (``compress_mode="per_worker"``, QSGD's real protocol, booked at
  ``nbytes`` × m) or the legacy post-reduction simulation
  (``"legacy"``, booked at one worker's ``nbytes``).
* ``make_zo_step``  — lowers the ZO round (eq. 4-6): partial-auto
  ``jax.shard_map`` (manual over worker axes).  Each worker evaluates the
  loss twice on its local shard, all-gathers **one scalar per worker**,
  regenerates every worker's direction from the pre-shared seed, and
  reconstructs the update locally.  Inter-worker traffic: 4*m bytes —
  independent of d.

On the synchronous full-membership path the lowered programs are
bit-identical to the pre-IR step functions (pinned by
``tests/test_rounds_equivalence.py``); the simulator replays the SAME
rounds per worker (``repro.sim.runner``) when membership or staleness
makes the monolithic fusion unfaithful.
"""
from __future__ import annotations

import math
import warnings
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.core import rounds
from repro.core.engine import make_engine
from repro.core.ho_sgd import HOSGDConfig
from repro.dist import collectives as coll
from repro.dist.compress import Compressor, compress_tree
from repro.dist.sharding import batch_specs, named, param_specs, worker_axes
from repro.opt.optimizers import Optimizer, apply_deltas, const_schedule, sgd


def _replicated_specs(tree: Any) -> Any:
    return jax.tree.map(lambda _: P(), tree)


def _mesh_workers(mesh: Mesh) -> int:
    # host-side mesh arithmetic: plain ints, never jax arrays
    return max(1, math.prod(mesh.shape[a] for a in worker_axes(mesh)))


def make_fo_step(
    loss_fn: Callable[[Any, Any], jax.Array],
    mesh: Mesh,
    opt: Optimizer,
    grad_accum: int = 1,
    scan_unroll: bool = False,
    compressor: Optional[Compressor] = None,
    seed: int = 0,
    compress_mode: str = "per_worker",
    m: Optional[int] = None,
    buckets: int = 1,
) -> Callable:
    """jit(train_step): (t, params, opt_state, batch) -> (params, state, loss).

    Lowers ``rounds.fo_round`` to the mesh.  ``grad_accum`` splits the batch
    into microbatches scanned sequentially with an fp32 gradient accumulator
    — bounds the backward residual stack (n_layers * tokens_mb * d_model per
    device) that dominates train memory.

    ``compressor`` hooks a QSGD/signSGD/top-k codec onto the gradient
    all-reduce through the round's wire hook.  ``compress_mode="per_worker"``
    (the faithful protocol) splits the batch over the ``m`` workers
    in-program, encodes each worker's shard gradient independently and
    decodes at the reducer — the step books ``nbytes`` × m wire bytes (each
    worker receives every worker's code).  Cost of that fidelity: the m
    shard gradients are materialized together (m× the gradient memory of
    the fused data-parallel path) and the m codec round-trips serialize —
    fine for the simulator's models and CPU rehearsals; pass
    ``compress_mode="legacy"`` (CLI ``--compress-mode legacy``) on
    LLM-scale meshes where the post-reduction approximation is the right
    trade.  ``"legacy"`` keeps the historical post-reduction simulation
    ``decode(encode(g))`` on the reduced gradient, booked at one worker's
    ``nbytes``; ``grad_accum > 1`` falls back to it with a warning (the
    microbatch scan collapses the per-worker gradients).  ``m`` defaults to
    the mesh's worker count; with ``m == 1`` the two modes coincide and the
    program is bit-identical to the uncompressed-era legacy path.

    ``buckets > 1`` (CLI ``--fo-buckets``) attaches a ``rounds.Overlap``
    spec and chunks the flat gradient into that many independently-reducible
    buckets before the optimizer update — pure data movement (bit-identical
    params, identical ledger bytes), but the gradient all-reduce GSPMD
    inserts splits into per-bucket reduces the async-collective /
    latency-hiding XLA scheduler (``launch.xla``) can overlap with compute.
    """
    rnd = rounds.fo_round(loss_fn, opt,
                          wire=rounds.Wire(compressor, compress_mode),
                          overlap=rounds.Overlap(buckets))
    return lower_fo_round(rnd, mesh, grad_accum=grad_accum,
                          scan_unroll=scan_unroll, seed=seed, m=m)


def _bucketed_reduce_form(grads: Any, buckets: int) -> Any:
    """Rewrite a gradient tree into its chunked flat-gradient reduce form.

    Flattens the tree into one flat vector, splits it into ``buckets``
    contiguous chunks (the last one shorter when the parameter count does
    not divide evenly), and reassembles the original tree from the chunk
    concatenation.  Values are bit-identical — this is pure data movement —
    but each chunk is an independent intermediate, so the GSPMD gradient
    all-reduce lowers to per-bucket reduces the latency-hiding scheduler
    can pipeline against compute (the real-path mirror of the sim's
    ``Overlap`` pricing).  Wire bytes are unchanged: same tree, same dtypes.
    """
    leaves, treedef = jax.tree.flatten(grads)
    flat = (jnp.concatenate([l.reshape(-1) for l in leaves])
            if len(leaves) > 1 else leaves[0].reshape(-1))
    n = flat.shape[0]
    size = max(1, -(-n // buckets))          # ceil; last chunk takes the rest
    chunks = [jax.lax.slice_in_dim(flat, lo, min(lo + size, n))
              for lo in range(0, n, size)]
    flat = jnp.concatenate(chunks) if len(chunks) > 1 else chunks[0]
    out, off = [], 0
    for l in leaves:
        out.append(jax.lax.slice_in_dim(flat, off, off + l.size).reshape(l.shape))
        off += l.size
    return jax.tree.unflatten(treedef, out)


def lower_fo_round(
    rnd: rounds.Round,
    mesh: Mesh,
    *,
    grad_accum: int = 1,
    scan_unroll: bool = False,
    seed: int = 0,
    m: Optional[int] = None,
) -> Callable:
    """Fuse an FO round's per-worker locals + all-reduce + apply into one
    data-parallel program (the gradient reduction is GSPMD-inserted).  The
    round's ``Overlap`` spec selects the chunked reduce form
    (``_bucketed_reduce_form``) — bit-identical math, same booked bytes."""
    loss_fn, opt = rnd.meta["loss_fn"], rnd.meta["opt"]
    compressor, mode = rnd.wire.codec, rnd.wire.mode
    buckets = getattr(rnd.overlap, "buckets", 1)
    m = m if m is not None else _mesh_workers(mesh)
    per_worker = compressor is not None and mode == "per_worker" and m > 1
    if per_worker and grad_accum > 1:
        # per-worker encoding needs the m shard gradients individually,
        # which the microbatch-scan accumulator collapses — fall back to
        # the legacy post-reduction codec instead of refusing to train
        # (previously-working --compress + grad_accum configs keep working)
        warnings.warn(
            "per-worker FO encoding does not compose with grad_accum > 1; "
            "falling back to compress_mode='legacy' (post-reduction codec)",
            stacklevel=2)
        per_worker = False

    def fo_step(t, params, opt_state, batch):
        if per_worker:
            # faithful per-worker encode: the m workers' shard gradients are
            # computed in-program, each encoded with its own key and decoded
            # at the reducer — every worker receives m codes (nbytes * m)
            mb = jax.tree.map(
                lambda x: x.reshape(m, x.shape[0] // m, *x.shape[1:]), batch)
            losses, grads_m = jax.vmap(
                lambda b: jax.value_and_grad(loss_fn)(params, b))(mb)
            key_t = jax.random.fold_in(jax.random.key(seed), t)
            dec, wire = [], 0
            for w in range(m):
                g_w = jax.tree.map(lambda x: x[w], grads_m)
                d_w, nb = compress_tree(compressor, g_w,
                                        jax.random.fold_in(key_t, w))
                dec.append(d_w)
                wire = nb * m
            grads = jax.tree.map(
                lambda *xs: jnp.mean(jnp.stack(
                    [x.astype(jnp.float32) for x in xs]), 0).astype(xs[0].dtype),
                *dec)
            loss = jnp.mean(losses)
            coll.note_all_reduce(grads, nbytes=wire, tag=compressor.name)
        elif grad_accum <= 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            # split so the *major* dim stays the (sharded) batch dim, then
            # transpose: reshape(accum, B/accum, ...) would force GSPMD to
            # split the data-axis sharding across microbatches (4-way-parallel
            # microbatches, constant memory); this keeps every device working
            # on its own rows in every microbatch.
            mb = jax.tree.map(
                lambda x: x.reshape(x.shape[0] // grad_accum, grad_accum,
                                    *x.shape[1:]).swapaxes(0, 1),
                batch,
            )

            def micro(carry, batch_i):
                g_acc, l_acc = carry
                l, g = jax.value_and_grad(loss_fn)(params, batch_i)
                g_acc = jax.tree.map(
                    lambda a, gg: a + gg.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + l), None

            init = (jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params), jnp.float32(0.0))
            (grads, loss), _ = jax.lax.scan(
                micro, init, mb, unroll=grad_accum if scan_unroll else 1)
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            loss = loss / grad_accum
        if not per_worker:
            # the d-dim gradient all-reduce is inserted by GSPMD (sharded
            # batch x replicated params); book its wire bytes — or the
            # codec's — here.
            if compressor is not None:
                grads, wire = compress_tree(
                    compressor, grads,
                    jax.random.fold_in(jax.random.key(seed), t))
                coll.note_all_reduce(grads, nbytes=wire, tag=compressor.name)
            else:
                coll.note_all_reduce(grads, tag="grads")
        if buckets > 1:
            grads = _bucketed_reduce_form(grads, buckets)
        deltas, opt_state = opt.update(grads, opt_state, params, t)
        return apply_deltas(params, deltas), opt_state, loss

    return fo_step


def make_zo_step(
    loss_fn: Callable[[Any, Any], jax.Array],
    mesh: Mesh,
    ho: HOSGDConfig,
    opt: Optimizer,
    m: Optional[int] = None,
    fsdp: bool = False,
    param_specs_tree: Any = None,
    vmap_workers: bool = False,
) -> Callable:
    """(t, params, opt_state, batch) -> (params, opt_state, loss).

    Lowers ``rounds.zo_round`` to the mesh.  The shard_map inner function
    returns the reconstructed gradient estimate (replicated across workers —
    every worker computes the same sum); the optimizer update composes
    outside, so HO-SGD's ZO steps can drive any optimizer (beyond-paper:
    ZO-Adam).

    The direction algebra itself lives in ``repro.core.engine`` — the
    backend is picked by ``ho.engine`` ('fused' keeps the direction out of
    program buffers; 'pallas' routes through the kernels; 'tree' is the
    reference; 'flat' packs the tree into one buffer and, with plain SGD on
    unsharded params, fuses the whole round into two kernel families on the
    auto-sharded branch) and the params' sharding specs are threaded into
    the engine
    so every hash-generated leaf and accumulator carries a sharding
    constraint (without one the partitioner is free to replicate the full
    d-dim direction per device — 1.8 TB fp32 for arctic).

    ``vmap_workers`` makes the 0.4.x auto-sharded fallback evaluate the m
    worker coefficients (and the reconstruction) under one vmap, keeping
    its HLO O(1) in m — the large-m CPU-rehearsal mode; the default stays
    unrolled, which is bit-compatible with the single-host reference.

    With ``fsdp`` params are sharded over the data axis, so a model replica
    (= the paper's "worker") spans (data, model) and the ZO step runs with
    m=1 (one global direction per iteration, plain pjit).  Running the pod
    axis as a manual worker axis is blocked by an XLA SPMD partitioner
    CHECK-failure when the MoE dispatch gathers meet subgroup-manual
    sharding (spmd_partitioner_util.cc:504; stack in EXPERIMENTS.md §Dry-run
    notes) — a real-XLA limitation we document rather than hide.
    """
    rnd = rounds.zo_round(loss_fn, ho, opt, m=m)
    return lower_zo_round(rnd, mesh, m=m, fsdp=fsdp,
                          param_specs_tree=param_specs_tree,
                          vmap_workers=vmap_workers)


def lower_zo_round(
    rnd: rounds.Round,
    mesh: Mesh,
    *,
    m: Optional[int] = None,
    fsdp: bool = False,
    param_specs_tree: Any = None,
    vmap_workers: bool = False,
) -> Callable:
    """Fuse a ZO round's per-worker coefficient evals + scalar all-gather +
    reconstruction into one program: the partial-auto shard_map path on new
    jax, the auto-sharded (GSPMD) fallback with the m evals in-program on
    0.4.x (``repro.compat``)."""
    loss_fn, ho, opt = (rnd.meta["loss_fn"], rnd.meta["ho"], rnd.meta["opt"])
    if fsdp:
        wa = ()
    else:
        wa = worker_axes(mesh)
    # host-side mesh arithmetic: plain ints, never jax arrays
    m = m or max(1, math.prod(mesh.shape[a] for a in wa))

    def engine_for(params):
        return make_engine(ho.engine, params, ho.seed,
                           specs=param_specs_tree, acc_dtype=ho.acc_dtype)

    def _scaled(eng, cs, t, vmap_w=False):
        rec = eng.reconstruct(cs, t, vmap_workers=vmap_w)
        return jax.tree.map(lambda a: a * (ho.zo_scale / m), rec)

    def zo_inner(t, params, batch_local):
        eng = engine_for(params)
        # worker id from the manual axes
        idx = jax.lax.axis_index(wa[0])
        if len(wa) == 2:
            idx = idx * mesh.shape[wa[1]] + jax.lax.axis_index(wa[1])
        c, f0 = eng.zo_coeff(loss_fn, params, batch_local, t,
                             idx.astype(jnp.uint32), ho.mu)
        cs = coll.all_gather(c, wa, tag="zo_coeffs")      # (m,) scalars — the
        cs = cs.reshape(-1)                               # paper's entire comm
        g_hat = _scaled(eng, cs, t)
        # averaging the monitoring loss is diagnostics, not Algorithm 1's
        # communication — booked as non-payload so measured bytes stay 4*m
        loss = coll.pmean(f0, wa, tag="loss", payload=False)
        return g_hat, loss

    def zo_single(t, params, batch):
        """m=1 degenerate case (fsdp arch on the single-pod mesh): plain pjit.

        One global direction means a one-scalar "gather" — booked so the
        ledger shows 4 bytes (the m=1 truth) rather than a silent 0 when an
        fsdp arch's ZO step runs; the gap vs. the mesh's nominal worker
        count is the documented fsdp limitation, and it should be visible.
        """
        eng = engine_for(params)
        c, f0 = eng.zo_coeff(loss_fn, params, batch, t, jnp.uint32(0), ho.mu)
        cs = coll.note("all_gather", c.reshape(1), tag="zo_coeffs")
        g_hat = _scaled(eng, cs, t)
        return g_hat, f0

    def zo_auto(t, params, batch):
        """Auto-sharded (GSPMD) formulation with identical semantics.

        jax 0.4.x's partitioner aborts on collectives inside a partial-auto
        shard_map (see repro.compat), so on old runtimes the m worker
        evaluations run in-program over the workers' batch slices and the
        coefficient exchange is left to GSPMD.  Same math, same directions,
        same (booked) communication — the m evals serialize in the program
        instead of running one-per-worker, a documented cost of the
        fallback, not of the method.  ``vmap_workers`` batches those m
        evaluations (and the reconstruction) under one vmap so the lowered
        HLO stays O(1) in m.
        """
        for x in jax.tree.leaves(batch):
            assert x.shape[0] % m == 0, \
                f"batch {x.shape} not divisible by m={m} workers"
        eng = engine_for(params)
        workers = jnp.arange(m, dtype=jnp.uint32)
        stacked = jax.tree.map(
            lambda x: x.reshape(m, x.shape[0] // m, *x.shape[1:]), batch)
        cs, f0s = eng.zo_coeffs(loss_fn, params, stacked, t, workers, ho.mu,
                                vmap_workers=vmap_workers)
        cs = coll.note("all_gather", cs, tag="zo_coeffs")
        g_hat = _scaled(eng, cs, t, vmap_w=vmap_workers)
        loss = coll.note("pmean", jnp.mean(f0s), tag="loss", payload=False)
        return g_hat, loss

    # Fused single-buffer path: engine='flat' + plain SGD + unsharded params
    # on the auto-sharded branch (the kernels run per-device, so sharded
    # meshes and the shard_map lowering keep the generic reconstruct-then-
    # opt.apply path — same math, pinned by the equivalence suite).
    fused_flat = (ho.engine == "flat" and opt.kind == "sgd"
                  and param_specs_tree is None)

    def zo_auto_flat(t, params, opt_state, batch):
        """zo_auto semantics with the flat engine's fused kernels: the
        packed buffer lives across the round, each perturb accumulates the
        tree-wide ||v||^2 in its own launch, and the reconstruction + SGD
        (+momentum) commit is one in-place kernel — the update vector never
        exists in HBM.  Booked communication is identical to ``zo_auto``
        (4*m coefficient bytes + the non-payload monitoring loss)."""
        for x in jax.tree.leaves(batch):
            assert x.shape[0] % m == 0, \
                f"batch {x.shape} not divisible by m={m} workers"
        eng = engine_for(params)
        workers = jnp.arange(m, dtype=jnp.uint32)
        stacked = jax.tree.map(
            lambda x: x.reshape(m, x.shape[0] // m, *x.shape[1:]), batch)
        buf = eng.pack(params)
        cs, invs, f0s = [], [], []
        for i in range(m):
            b_i = jax.tree.map(lambda x: x[i], stacked)
            f0 = loss_fn(params, b_i)
            pbuf, ss = eng.fused_perturb_sumsq(buf, t, workers[i], ho.mu)
            f1 = loss_fn(eng.unpack(pbuf), b_i)
            cs.append(((eng.dim / ho.mu) * (f1 - f0)).astype(jnp.float32))
            invs.append(jax.lax.rsqrt(ss + 1e-30))
            f0s.append(f0)
        cs = coll.note("all_gather", jnp.stack(cs), tag="zo_coeffs")
        scaled = cs * jnp.stack(invs) * jnp.float32(ho.zo_scale / m)
        loss = coll.note("pmean", jnp.mean(jnp.stack(f0s)), tag="loss",
                         payload=False)
        momentum = float(opt.hyper["momentum"])
        mom = eng.pack(opt_state) if momentum else None
        buf, mom = eng.fused_reconstruct_update(
            buf, mom, t, workers, scaled, opt.hyper["schedule"](t), momentum)
        opt_state = eng.unpack(mom, cast=False) if momentum else opt_state
        return eng.unpack(buf), opt_state, loss

    def zo_step(t, params, opt_state, batch):
        if not wa:
            g_hat, loss = zo_single(t, params, batch)
        elif not compat.HAS_PARTIAL_AUTO_COLLECTIVES:
            if fused_flat:
                return zo_auto_flat(t, params, opt_state, batch)
            g_hat, loss = zo_auto(t, params, batch)
        else:
            params_specs = _replicated_specs(params)
            bspecs = jax.tree.map(
                lambda x: P(wa, *([None] * (x.ndim - 1))), batch)
            g_hat, loss = compat.shard_map(
                partial(zo_inner, t),
                mesh=mesh,
                in_specs=(params_specs, bspecs),
                out_specs=(params_specs, P()),
                axis_names=set(wa),
                check_vma=False,
            )(params, batch)
        deltas, opt_state = opt.update(g_hat, opt_state, params, t)
        return apply_deltas(params, deltas), opt_state, loss

    return zo_step


def make_distributed_ho_sgd(
    loss_fn: Callable,
    mesh: Mesh,
    ho: HOSGDConfig,
    opt: Optional[Optimizer] = None,
    model_cfg=None,
    params_like: Any = None,
    compressor: Optional[Compressor] = None,
    vmap_workers: bool = False,
    compress_mode: str = "per_worker",
    fo_buckets: int = 1,
):
    """Returns (fo_step, zo_step) honoring the arch's production knobs.

    ``compressor`` (repro.dist.compress) quantizes the FO gradient exchange
    (``compress_mode``: per-worker encode + reducer decode, or the legacy
    post-reduction simulation); the ZO step is untouched — its traffic is
    already one scalar per worker.  ``fo_buckets > 1`` lowers the FO round
    in its chunked reduce form (bit-identical math, same bytes) for the
    async-collective/latency-hiding XLA scheduler to overlap.
    """
    opt = opt or sgd(const_schedule(ho.lr), ho.momentum)
    ga = getattr(model_cfg, "grad_accum", 1) if model_cfg is not None else 1
    su = getattr(model_cfg, "scan_unroll", False) if model_cfg is not None else False
    fsdp = getattr(model_cfg, "fsdp", False) if model_cfg is not None else False
    specs = None
    if model_cfg is not None and params_like is not None:
        specs = param_specs(model_cfg, params_like, mesh)
    fo = make_fo_step(loss_fn, mesh, opt, grad_accum=ga, scan_unroll=su,
                      compressor=compressor, seed=ho.seed,
                      compress_mode=compress_mode, buckets=fo_buckets)
    zo = make_zo_step(loss_fn, mesh, ho, opt, fsdp=fsdp, param_specs_tree=specs,
                      vmap_workers=vmap_workers)
    return fo, zo


def jit_with_shardings(step_fn, mesh: Mesh, cfg_model, params, opt_state, batch,
                       donate: bool = True):
    """jit a (t, params, opt_state, batch) step with explicit shardings."""
    pspecs = param_specs(cfg_model, params, mesh)
    o_specs = jax.tree.map(lambda x: NamedSharding(mesh, P()), opt_state) if opt_state is not None else None
    in_sh = (
        NamedSharding(mesh, P()),
        named(mesh, pspecs),
        o_specs,
        named(mesh, batch_specs(mesh, batch)),
    )
    out_sh = (named(mesh, pspecs), o_specs, NamedSharding(mesh, P()))
    return jax.jit(
        step_fn,
        in_shardings=in_sh,
        out_shardings=out_sh,
        donate_argnums=(1, 2) if donate else (),
    )
