"""HO-SGD (Algorithm 1) — the paper's contribution, plus its two endpoints.

This module is the *single-host reference* implementation: the m workers of
Algorithm 1 are simulated faithfully (worker i uses its own batch shard and
its own pre-shared-seed direction).  The mesh-distributed implementation with
identical semantics lives in ``repro.core.distributed`` (partial-auto
shard_map; scalars move over the (pod, data) axes).

Communication accounting (per worker, per iteration, in scalars):
  * FO iteration: d              (the gradient vector — all-reduce)
  * ZO iteration: 1              (the directional-derivative coefficient)
so a period of tau iterations costs d + (tau-1) scalars — Table 1's
(tau - 1 + d)/tau per-iteration load.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.engine import make_engine
from repro.opt.optimizers import Optimizer, apply_deltas, const_schedule, sgd


@dataclass(frozen=True)
class HOSGDConfig:
    tau: int                 # period of first-order updates (tau=1 -> syncSGD)
    mu: float = 1e-3         # smoothing parameter
    m: int = 4               # number of workers
    seed: int = 0            # the pre-shared seed
    lr: float = 0.01
    zo_lr: Optional[float] = None  # ZO-step lr (the estimator's variance is
    momentum: float = 0.0          # O(d) larger; practice uses ~lr/d — the
                                   # paper's attack experiment uses 30/d)
    # dtype of the distributed ZO reconstruction accumulator.  fp32 is the
    # faithful default; bf16 halves the largest ZO-step resident (the
    # estimate is O(d)-noisy anyway) — beyond-paper memory lever (§Perf).
    acc_dtype: str = "float32"
    # DirectionEngine backend for the ZO direction algebra ('tree' | 'fused'
    # | 'pallas' | 'flat'; see repro.core.engine).  All backends are
    # numerically equivalent; 'fused' keeps the direction out of program
    # buffers and its HLO O(1) in m, 'pallas' additionally keeps it out of
    # HBM on TPU, and 'flat' packs the tree into one buffer and (for plain
    # SGD) fuses the whole ZO round — perturb+sumsq in one launch,
    # reconstruct+optimizer commit in one launch on donated buffers.
    engine: str = "fused"

    @property
    def zo_scale(self) -> float:
        return 1.0 if self.zo_lr is None else self.zo_lr / self.lr

    @property
    def is_first_order_only(self) -> bool:
        return self.tau == 1


class Method(NamedTuple):
    """Uniform optimizer-method interface used by benchmarks and tests."""
    name: str
    init: Callable[[Any], Any]                    # params -> state
    step: Callable[..., tuple]                    # (t, params, state, batch[, key])
    # analytic per-iteration cost model (scalars / func evals / grad evals):
    comm_scalars: Callable[[int], float]
    fevals: Callable[[int], float]
    gevals: Callable[[int], float]
    # the per-worker round program this method was built from, when it was
    # (repro.core.rounds) — the simulator's per-worker replay handle
    program: Optional[Any] = None


def _split_workers(batch: Any, m: int) -> Any:
    """(m*B, ...) -> (m, B, ...) on every leaf."""
    def r(x):
        assert x.shape[0] % m == 0, f"batch {x.shape} not divisible by m={m}"
        return x.reshape(m, x.shape[0] // m, *x.shape[1:])
    return jax.tree.map(r, batch)


def make_ho_sgd(
    loss_fn: Callable[[Any, Any], jax.Array],
    cfg: HOSGDConfig,
    opt: Optional[Optimizer] = None,
    name: str = "ho_sgd",
) -> Method:
    opt = opt or sgd(const_schedule(cfg.lr), cfg.momentum)

    @jax.jit
    def fo_step(t, params, opt_state, batch):
        """Eq. (3): all workers' first-order grads, averaged (data-parallel)."""
        flat = jax.tree.map(lambda x: x.reshape(-1, *x.shape[2:]), batch)
        loss, grads = jax.value_and_grad(loss_fn)(params, flat)
        deltas, opt_state = opt.update(grads, opt_state, params, t)
        return apply_deltas(params, deltas), opt_state, loss

    # The flat engine's fused step path needs introspectable SGD semantics
    # (the momentum update runs in-kernel); any other optimizer — or any
    # other engine — takes the generic reconstruct-then-opt.apply path.
    fused_flat = cfg.engine == "flat" and opt.kind == "sgd"

    @jax.jit
    def zo_step(t, params, opt_state, batch):
        """Eq. (4)-(6): per-worker scalar coefficients, shared reconstruction."""
        eng = make_engine(cfg.engine, params, cfg.seed, acc_dtype=cfg.acc_dtype)
        workers = jnp.arange(cfg.m, dtype=jnp.uint32)
        if fused_flat:
            return zo_step_flat(eng, workers, t, params, opt_state, batch)
        cs, f0s = eng.zo_coeffs(loss_fn, params, batch, t, workers, cfg.mu)
        g_hat = jax.tree.map(
            lambda a: a * (cfg.zo_scale / cfg.m), eng.reconstruct(cs, t))
        deltas, opt_state = opt.update(g_hat, opt_state, params, t)
        return apply_deltas(params, deltas), opt_state, jnp.mean(f0s)

    def zo_step_flat(eng, workers, t, params, opt_state, batch):
        """Single-buffer fused ZO round (engine='flat', plain SGD).

        The packed buffer lives across the whole round: each worker's
        perturb accumulates the tree-wide ||v||^2 in the same launch (no
        separate inv-norm pass over d), and the reconstruction + SGD
        (+momentum) commit is one in-place kernel on donated buffers — the
        update vector never exists in HBM.  The kernel-side sumsq has a
        different (blockwise) reduction order than the shared jnp one, so
        this path is loss-equivalent — not bitwise — to the per-primitive
        engines (pinned in tests/test_engine.py).
        """
        momentum = float(opt.hyper["momentum"])
        buf = eng.pack(params)
        cs, f0s = [], []
        for i in range(cfg.m):
            b_i = jax.tree.map(lambda x: x[i], batch)
            f0 = loss_fn(params, b_i)
            pbuf, ss = eng.fused_perturb_sumsq(buf, t, workers[i], cfg.mu)
            f1 = loss_fn(eng.unpack(pbuf), b_i)
            c = ((eng.dim / cfg.mu) * (f1 - f0)).astype(jnp.float32)
            cs.append(c * jax.lax.rsqrt(ss + 1e-30))
            f0s.append(f0)
        scaled = jnp.stack(cs) * jnp.float32(cfg.zo_scale / cfg.m)
        lr = opt.hyper["schedule"](t)
        mom = eng.pack(opt_state) if momentum else None
        buf, mom = eng.fused_reconstruct_update(
            buf, mom, t, workers, scaled, lr, momentum)
        opt_state = eng.unpack(mom, cast=False) if momentum else opt_state
        return eng.unpack(buf), opt_state, jnp.mean(jnp.stack(f0s))

    def init(params):
        return opt.init(params)

    def step(t: int, params, state, batch, key=None):
        batch = _split_workers(batch, cfg.m)
        if t % cfg.tau == 0:
            params, state, loss = fo_step(jnp.int32(t), params, state, batch)
            metrics = {"loss": loss, "order": 1}
        else:
            params, state, loss = zo_step(jnp.int32(t), params, state, batch)
            metrics = {"loss": loss, "order": 0}
        return params, state, metrics

    def comm_scalars(d: int) -> float:   # amortized per iteration per worker
        return (d + (cfg.tau - 1)) / cfg.tau

    def fevals(d: int) -> float:         # function evals per iter per worker
        return 2 * (cfg.tau - 1) / cfg.tau

    def gevals(d: int) -> float:         # first-order grad evals per iter
        return 1.0 / cfg.tau

    return Method(name, init, step, comm_scalars, fevals, gevals)


def adaptive_tau_decision(t: int, since_fo: int, tau_t: int,
                          base_tau: int) -> tuple:
    """One adaptive-tau scheduling decision: ``(is_fo, t_step, new_since_fo)``.

    The single home of the adaptive-period logic — ``make_adaptive_ho_sgd``,
    ``launch.train --tau-schedule`` and the ``repro.sim`` runner all route
    through here, so the simulator provably exercises the same schedule as
    the real trainer.  ``t_step`` is the iteration index to hand the
    underlying step program: FO steps map onto multiples of ``base_tau``
    (t=0 always FO); ZO steps map t to the t-th positive integer not
    divisible by ``base_tau`` — injective, so no two adaptive ZO steps ever
    share a direction seed (t+1 collided with the next step whenever t was a
    multiple of ``base_tau``: identical perturbations twice).
    """
    assert base_tau > 1, "adaptive tau needs a base period >= 2"
    if t == 0 or since_fo + 1 >= max(1, int(tau_t)):
        return True, (0 if t == 0 else base_tau * max(t, 1)), 0
    return False, t + 1 + t // (base_tau - 1), since_fo + 1


def parse_tau_schedule(spec: str) -> Callable[[int], int]:
    """``'const:8'`` or ``'linear:2,16,1000'`` -> tau(t).

    ``linear:start,end,horizon`` ramps the period linearly from ``start`` at
    t=0 to ``end`` at t >= ``horizon`` — the growing-then-capped schedule
    that front-loads cheap ZO steps (the ZO approximation error matters
    most late in training: small gradients vs O(d) estimator variance).
    """
    kind, _, arg = spec.partition(":")
    if kind == "const":
        tau = int(arg)
        assert tau >= 1, f"const tau must be >= 1, got {tau}"
        return lambda t: tau
    if kind == "linear":
        start, end, horizon = (int(x) for x in arg.split(","))
        assert start >= 1 and end >= 1 and horizon >= 1, spec
        return lambda t: int(round(start + (end - start) * min(t, horizon)
                                   / horizon))
    raise ValueError(f"unknown tau schedule {spec!r}; use 'const:K' or "
                     f"'linear:start,end,horizon'")


def make_adaptive_ho_sgd(
    loss_fn: Callable,
    cfg: HOSGDConfig,
    tau_schedule: Callable[[int], int],
    opt: Optional[Optimizer] = None,
) -> Method:
    """Beyond-paper: HO-SGD with a time-varying period tau(t).

    The paper fixes tau; ``tau_schedule(t)`` returns the current period and
    an FO step fires whenever the position within the current period wraps
    (decision logic in ``adaptive_tau_decision``).
    """
    # the base method's ZO branch is keyed on t % cfg.tau != 0 — with tau=1
    # it is unreachable and every "ZO" step would silently run fo_step
    assert cfg.tau > 1, "make_adaptive_ho_sgd needs cfg.tau >= 2"
    base = make_ho_sgd(loss_fn, cfg, opt, name="ho_sgd_adaptive")

    # The since-FO counter lives IN the method state (not a closure): two
    # run_method calls on the same Method must not leak schedule position
    # into each other, and init() must restart the schedule from an FO step.
    def init(params):
        return {"base": base.init(params), "since_fo": 0}

    def step(t: int, params, state, batch, key=None):
        _, t_step, since_fo = adaptive_tau_decision(
            t, int(state["since_fo"]), tau_schedule(t), cfg.tau)
        params, bstate, metrics = base.step(t_step, params, state["base"],
                                            batch, key)
        return params, {"base": bstate, "since_fo": since_fo}, metrics

    return base._replace(name="ho_sgd_adaptive", init=init, step=step)


def make_sync_sgd(loss_fn, m: int, lr: float, momentum: float = 0.0) -> Method:
    """Fully synchronous distributed SGD (Wang & Joshi 2018) = HO-SGD, tau=1."""
    cfg = HOSGDConfig(tau=1, m=m, lr=lr, momentum=momentum)
    meth = make_ho_sgd(loss_fn, cfg, name="sync_sgd")
    return meth._replace(
        comm_scalars=lambda d: float(d), fevals=lambda d: 0.0, gevals=lambda d: 1.0
    )


def make_zo_sgd(loss_fn, m: int, mu: float, lr: float, seed: int = 0) -> Method:
    """Distributed ZO-SGD (Sahu et al. 2019) = HO-SGD, tau >= N (never FO)."""
    cfg = HOSGDConfig(tau=1 << 30, mu=mu, m=m, lr=lr, seed=seed)
    meth = make_ho_sgd(loss_fn, cfg, name="zo_sgd")
    return meth._replace(
        comm_scalars=lambda d: 1.0, fevals=lambda d: 2.0, gevals=lambda d: 0.0
    )


def run_method(
    method: Method,
    params: Any,
    batches,                       # iterable of (m*B, ...) batches
    n_iters: int,
    eval_fn: Optional[Callable] = None,
    eval_every: int = 0,
    key=None,
) -> Dict[str, list]:
    """Simple training loop collecting per-iteration history."""
    state = method.init(params)
    hist: Dict[str, list] = {"loss": [], "order": [], "eval": []}
    it = iter(batches)
    for t in range(n_iters):
        batch = next(it)
        params, state, metrics = method.step(t, params, state, batch, key)
        hist["loss"].append(float(metrics["loss"]))
        hist["order"].append(int(metrics["order"]))
        if eval_fn and eval_every and (t + 1) % eval_every == 0:
            hist["eval"].append((t + 1, float(eval_fn(params))))
    hist["params"] = params
    return hist
