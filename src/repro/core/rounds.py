"""Round-program IR: one per-worker method representation shared by the
distributed runtime, the baselines, and the simulator.

The paper's method is a *schedule of per-worker rounds*: ZO rounds where each
worker contributes a directional-derivative scalar in a pre-shared direction,
punctuated by FO gradient syncs.  Before this module the repo encoded that
schedule three times — as monolithic all-m-workers step programs in
``core.distributed``, as vmapped single-host loops in ``core.baselines``, and
implicitly in ``repro.sim``'s replay (which therefore could reprice
async/elastic scenarios but never change the computed trajectory).  A method
is now written ONCE as a ``RoundProgram``:

  * ``init(params) -> state`` and a host-side schedule
    ``round_for(t, state) -> RoundStep`` picking this iteration's ``Round``;
  * each ``Round`` is a per-worker ``local(t, worker, model, shard) ->
    (payload, aux)`` plus a collective op — ``all_reduce`` (mean of
    payloads), ``all_gather`` (stacked payloads), ``tree_average`` (model
    tree averaging), ``masked_average`` (per-coordinate weighted average
    over the workers that sent a nonzero value — the federated
    FedDropoutAvg commit), ``neighbor_exchange`` (ring-gossip mixing) or
    ``none`` — with an explicit wire codec hook (``Wire``);
  * ``apply(t, params, state, reduced, workers, aux)`` commits the reduced
    payload into the global ``(params, state)``.

Consumers (README §RoundProgram):

  * ``core.distributed.make_fo_step`` / ``make_zo_step`` LOWER the HO-SGD
    rounds to the mesh (shard_map or the 0.4.x auto-sharded fallback) —
    the whole schedule fuses into monolithic jitted programs, bit-identical
    to the pre-IR step functions on the synchronous full-membership path.
  * ``core.baselines`` builds PA/RI/QSGD (and gossip-PA) as round programs
    and derives their single-host ``Method`` via ``to_method``.
  * ``repro.sim.runner`` replays rounds PER WORKER through a
    ``RoundExecutor`` so bounded-staleness and elastic membership feed each
    worker the params/membership it actually has — trajectories genuinely
    diverge instead of only being repriced, and the live-W collective
    prices the payload each active worker actually sent.

Wire accounting follows the ``CommLedger`` receive convention (bytes
received per worker per collective):

  * ``all_gather``  — bytes of the gathered result: payload × n_active;
  * ``all_reduce``  — dense: bytes of the reduced payload (independent of
    W); with a per-worker codec: ``codec.nbytes`` × n_active (each worker
    receives every active worker's code — QSGD's real protocol); with the
    legacy post-reduction codec: ``codec.nbytes`` × 1;
  * ``tree_average`` — dense: bytes of the averaged model tree; with a
    per-worker codec: ``codec.nbytes`` × n_active (the reducer receives
    every active worker's encoded tree); legacy: ``codec.nbytes`` × 1;
  * ``masked_average`` — per-client payload bytes (codec bytes when a
    codec rides the wire, dense otherwise) × n_active: exactly what the
    live sampled cohort uploads, never × the client population N;
  * ``neighbor_exchange`` — min(2, W-1) neighbor payloads per worker;
  * ``none`` — 0.

A ``Wire`` codec only composes with the collectives that actually move an
encodable payload — see ``CODEC_COLLECTIVES``; ``Round.__post_init__``
fails fast on any other (collective, codec) pairing instead of silently
booking dense bytes.

The executor both returns the byte count (``metrics["comm_bytes"]``) and
books it through ``repro.dist.collectives.note`` so a ledger-wrapped replay
records the identical number — the wire model lives in exactly one place.

Federated partial participation (``core.federated``): a ``RoundProgram``
with a ``client_sampling`` spec runs each round over a freshly sampled
K-of-N client cohort — the executor draws the cohort, feeds every sampled
client its own data shard (``federated.cohort_shards``), and weighs the
``masked_average`` commit by client dataset size.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.ho_sgd import Method, _split_workers
from repro.dist import collectives as coll
from repro.dist.collectives import _tree_nbytes
from repro.dist.compress import Compressor, compress_tree

#: collective ops a Round may request (the executor's reduce semantics)
COLLECTIVES = ("all_reduce", "all_gather", "tree_average", "masked_average",
               "neighbor_exchange", "none")

#: the (collective, codec) support matrix: collectives a ``Wire`` codec
#: composes with — both booked by ``wire_nbytes`` and round-tripped by
#: ``reduce_payloads``.  ``all_gather`` moves raw (typically scalar)
#: payloads and ``none`` moves nothing; a codec there would silently book
#: dense bytes, so ``Round.__post_init__`` rejects it.
CODEC_COLLECTIVES = ("all_reduce", "tree_average", "masked_average",
                     "neighbor_exchange")

#: wire codec application modes
WIRE_MODES = ("per_worker", "legacy")


@dataclass(frozen=True)
class Wire:
    """How a round's payload crosses the wire.

    ``per_worker`` encodes every worker's payload independently and decodes
    at the reducer (the faithful QSGD/signSGD protocol: per-worker wire
    bytes = ``codec.nbytes`` × active workers).  ``legacy`` keeps the
    historical post-reduction simulation — ``decode(encode(mean))`` on the
    already-reduced payload, booked at one worker's wire bytes.

    ``seed`` roots the per-worker encode keys (``fold(key(seed), t,
    worker_id)`` — the worker ID, not its position in the live membership,
    so a worker's quantization stream survives other workers leaving, and
    matches the mesh lowering's keys).
    """

    codec: Optional[Compressor] = None
    mode: str = "per_worker"
    seed: int = 0

    def __post_init__(self):
        assert self.mode in WIRE_MODES, \
            f"unknown wire mode {self.mode!r}; have {WIRE_MODES}"


@dataclass(frozen=True)
class Overlap:
    """Compute/communication overlap spec for a round.

    ``buckets > 1`` splits the round's wire payload into that many buckets:
    bucket k's collective may run concurrently with the local compute that
    produces chunk k+1, so only the tail of the collective is exposed on
    the critical path.  ``buckets=1`` is the strict compute-then-communicate
    round (the historical price, and the default).

    The spec changes *time only, never bytes*: the simulator prices an
    overlapped round as ``compute + max(0, comm − compute·(B−1)/B)`` (the
    first chunk must finish before the first bucket can depart; see
    ``sim.costs.exposed_comm_time``), the mesh lowering chunks the flat
    gradient into ``B`` independently-reducible buckets
    (``core.distributed.lower_fo_round``), and the ``CommLedger`` books the
    identical wire bytes either way — pinned in ``tests/test_comm_ledger.py``.
    """

    buckets: int = 1

    def __post_init__(self):
        assert self.buckets >= 1, f"overlap buckets must be >= 1, got {self.buckets}"

    @property
    def enabled(self) -> bool:
        return self.buckets > 1

    @property
    def overlappable_fraction(self) -> float:
        """Fraction of the round's local compute a pipelined collective can
        hide behind: (B−1)/B — chunk 1 must exist before bucket 1 departs."""
        return (self.buckets - 1) / self.buckets


@dataclass(frozen=True, eq=False)
class Round:
    """One per-worker round: local computation + collective + apply.

    ``eq=False`` keeps object identity for ``__eq__``/``__hash__``: rounds
    are compared and cached (``RoundExecutor``'s jit caches) by the object
    itself, which both matches the semantics (two rounds with identical
    fields still close over distinct jitted ``local``s) and pins a strong
    reference in the cache — a dynamically built round can never alias a
    dead round's cache entry the way the historical ``id(rnd)`` keys could.

    ``local(t, worker, model, shard) -> (payload, aux)`` runs on each
    participating worker; ``model`` is the worker's model view — the global
    params for data-parallel methods, the worker's own replica (from
    ``state["replicas"]``) when ``replica=True``.  ``aux`` is a monitoring
    scalar (typically the local loss) — diagnostics, never part of the
    algorithm's communication (booked ``payload=False``, like the loss
    pmean in the distributed ZO step).

    ``apply(t, params, state, reduced, workers, aux)`` commits the round:
    ``reduced`` is the collective's output, ``workers`` the uint32 array of
    contributing worker ids (the live membership under elastic execution),
    ``aux`` the worker-stacked aux values.  Programs jit their own apply
    internals; host-side schedule state (e.g. ``since_fo``) stays out of it
    (see ``RoundStep.host_updates``).

    ``meta`` carries builder configuration for lowerings (e.g. the
    ``HOSGDConfig`` the mesh lowering of a ZO round needs) — opaque to the
    executor.
    """

    tag: str
    order: int                       # 1 = gradient round, 0 = function-eval
    collective: str
    local: Callable[..., Tuple[Any, Any]]
    apply: Callable[..., Tuple[Any, Any, Dict[str, Any]]]
    wire: Wire = field(default_factory=Wire)
    replica: bool = False
    meta: Any = None
    overlap: Overlap = field(default_factory=Overlap)

    def __post_init__(self):
        assert self.collective in COLLECTIVES, \
            f"unknown collective {self.collective!r}; have {COLLECTIVES}"
        if self.wire.codec is not None:
            assert self.collective in CODEC_COLLECTIVES, (
                f"a Wire codec ({self.wire.codec.name!r}) is not supported "
                f"on collective {self.collective!r}: codecs compose with "
                f"{CODEC_COLLECTIVES} (all_gather moves raw payloads, "
                f"'none' moves nothing — dense booking would silently "
                f"misreport compression)")
        if self.collective == "masked_average":
            assert self.wire.mode == "per_worker", (
                "masked_average is inherently per-client: each sampled "
                "client uploads its own (possibly masked) payload; the "
                "legacy post-reduction wire mode has no meaning here")


class RoundStep(NamedTuple):
    """One scheduled iteration: the round, the iteration index to run it at
    (``t_step`` — the adaptive-tau seed mapping), and host-side state
    updates the executor merges AFTER ``apply`` (python scalars such as the
    ``since_fo`` counter, kept out of jitted code so checkpoints keep
    canonical python leaves)."""

    round: Round
    t_step: int
    host_updates: Dict[str, Any]


@dataclass(frozen=True)
class RoundProgram:
    """A method as ``init`` + a schedule of per-worker rounds.

    ``round_for(t, state)`` is a PURE host-side function — the executor (and
    the simulator, which peeks at the coming round's order for pricing) may
    call it repeatedly for the same ``(t, state)``.  ``prepare(t, batch,
    key)`` optionally transforms the global batch before sharding (RI-SGD's
    redundancy mixing).  ``comm_scalars``/``fevals``/``gevals`` are the
    Table-1 analytic per-iteration cost hooks (``Method`` compatibility).

    ``client_sampling`` (a ``core.federated.ClientSampling``, default None)
    makes the program federated: ``m`` must equal the spec's ``cohort_k``
    (the worker slots ARE the sampled cohort), and the executor draws each
    round's live cohort from the spec instead of assuming workers 0..m-1,
    feeding every sampled client its own identity-keyed data shard.
    """

    name: str
    m: int
    init: Callable[[Any], Any]
    round_for: Callable[[int, Any], RoundStep]
    comm_scalars: Callable[[int], float]
    fevals: Callable[[int], float]
    gevals: Callable[[int], float]
    prepare: Optional[Callable[[int, Any, Any], Any]] = None
    client_sampling: Any = None

    def __post_init__(self):
        if self.client_sampling is not None:
            assert self.client_sampling.cohort_k == self.m, (
                f"federated program {self.name!r}: m={self.m} must equal "
                f"cohort_k={self.client_sampling.cohort_k} — the worker "
                f"slots are the sampled cohort")


# --------------------------------------------------------------------------- #
# shared helpers
# --------------------------------------------------------------------------- #
#: (m*B, ...) -> (m, B, ...) on every leaf (worker i owns row i) — the ONE
#: sharding convention, shared with the monolithic reference step
#: (``repro.core.ho_sgd._split_workers``)
split_shards = _split_workers


def _stack_trees(trees: Sequence[Any]) -> Any:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _slice_tree(tree: Any, idx) -> Any:
    return jax.tree.map(lambda x: x[idx], tree)


def payload_nbytes(payload_slice: Any) -> int:
    """Dense wire bytes of ONE worker's payload tree."""
    return _tree_nbytes(payload_slice)


def codec_nbytes(codec: Compressor, payload_slice: Any) -> int:
    """Codec wire bytes of ONE worker's payload tree (per-leaf wire model)."""
    return sum(codec.nbytes(int(x.size))
               for x in jax.tree.leaves(payload_slice))


def wire_nbytes(rnd: Round, payload_slice: Any, n_active: int) -> int:
    """Bytes received per worker for this round's collective — the one wire
    model both the executor's booking and the simulator's pricing use."""
    if rnd.collective == "none" or n_active <= 0:
        return 0
    dense = payload_nbytes(payload_slice)
    codec = rnd.wire.codec
    if rnd.collective == "all_gather":
        return dense * n_active
    if rnd.collective == "all_reduce":
        if codec is None:
            return dense
        per = codec_nbytes(codec, payload_slice)
        return per * (n_active if rnd.wire.mode == "per_worker" else 1)
    if rnd.collective == "tree_average":
        if codec is None:
            return dense
        per = codec_nbytes(codec, payload_slice)
        return per * (n_active if rnd.wire.mode == "per_worker" else 1)
    if rnd.collective == "masked_average":
        # the sampled cohort's uploads: per-client payload × |live cohort|,
        # NEVER × the client population N
        per = dense if codec is None else codec_nbytes(codec, payload_slice)
        return per * n_active
    if rnd.collective == "neighbor_exchange":
        k = min(2, n_active - 1)
        per = dense if codec is None else codec_nbytes(codec, payload_slice)
        return per * k
    raise AssertionError(rnd.collective)


def neighbor_mix(stacked: Any, n_active: int) -> Any:
    """Ring-gossip mixing over the ACTIVE workers in listed order: worker j's
    result is the mean of its own payload and its ring neighbors'
    (``(P[j-1] + P[j] + P[j+1]) / 3``; with two workers the single neighbor,
    with one itself).  fp32 accumulation, cast back to the payload dtype."""
    if n_active == 1:
        return stacked

    def mix(x):
        x32 = x.astype(jnp.float32)
        left = jnp.roll(x32, 1, axis=0)
        right = jnp.roll(x32, -1, axis=0)
        if n_active == 2:          # left and right are the same worker
            out = (x32 + left) / 2.0
        else:
            out = (left + x32 + right) / 3.0
        return out.astype(x.dtype)

    return jax.tree.map(mix, stacked)


def masked_average(stacked: Any, weights) -> Tuple[Any, Any]:
    """FedDropoutAvg's masked weighted average over a worker-stacked tree.

    Per coordinate: ``avg = Σ_c w_c·x_c / Σ_c w_c·1[x_c ≠ 0]`` — each
    client's weight (``weights[c]``, typically its dataset size) counts
    only toward the coordinates it actually sent; a zero value is an
    absent value (FedDropoutAvg's sub-model semantics).  Returns
    ``(avg, wsum)`` trees: ``wsum`` is the per-coordinate surviving weight
    mass so ``apply`` can keep the server value where nobody contributed
    (``wsum == 0`` ⇒ ``avg == 0`` there).  fp32 accumulation, cast back.
    """
    w = jnp.asarray(weights, jnp.float32)

    def num_den(x):
        x32 = x.astype(jnp.float32)
        wb = w.reshape((w.shape[0],) + (1,) * (x32.ndim - 1))
        num = jnp.sum(x32 * wb, axis=0)
        den = jnp.sum(jnp.where(x32 != 0, wb, 0.0), axis=0)
        return num, den

    def avg_leaf(x):
        num, den = num_den(x)
        out = jnp.where(den > 0, num / jnp.where(den > 0, den, 1.0), 0.0)
        return out.astype(x.dtype)

    avg = jax.tree.map(avg_leaf, stacked)
    wsum = jax.tree.map(lambda x: num_den(x)[1], stacked)
    return avg, wsum


def _wire_key(wire: Wire, key, t) -> jax.Array:
    base = key if key is not None else jax.random.key(wire.seed)
    return jax.random.fold_in(base, t)


def wire_roundtrip(wire: Wire, stacked: Any, workers: Sequence[int],
                   key_t) -> Any:
    """Per-worker encode + reducer decode of a worker-stacked payload tree.

    Each worker's slice goes through ``decode(encode(.))`` with its own key
    — ``fold_in(key_t, worker_id)``, keyed on the worker's IDENTITY so the
    stream is invariant to who else is in the live membership (and matches
    the mesh lowering's per-worker keys).  No-op without a codec or in
    legacy mode (legacy decodes after the reduction instead)."""
    if wire.codec is None or wire.mode != "per_worker":
        return stacked
    outs = []
    for j, w in enumerate(workers):
        dec, _ = compress_tree(wire.codec, _slice_tree(stacked, j),
                               jax.random.fold_in(key_t, int(w)))
        outs.append(dec)
    return _stack_trees(outs)


def reduce_payloads(rnd: Round, stacked: Any, workers: Sequence[int],
                    key_t, weights=None) -> Any:
    """Apply the wire codec and the round's collective to a worker-stacked
    payload tree; returns what ``apply`` receives as ``reduced``.

    ``weights`` (len(workers), default uniform) only matters for
    ``masked_average`` — the per-client weight of the masked weighted
    average (client dataset sizes under ``ClientSampling``)."""
    n_active = len(workers)
    if rnd.collective in ("none", "all_gather"):
        return stacked
    if rnd.collective == "neighbor_exchange":
        stacked = wire_roundtrip(rnd.wire, stacked, workers, key_t)
        return neighbor_mix(stacked, n_active)
    if rnd.collective == "masked_average":
        stacked = wire_roundtrip(rnd.wire, stacked, workers, key_t)
        if weights is None:
            weights = jnp.ones((n_active,), jnp.float32)
        return masked_average(stacked, weights)
    # all_reduce / tree_average: mean over the contributing workers
    stacked = wire_roundtrip(rnd.wire, stacked, workers, key_t)
    mean = jax.tree.map(
        lambda x: jnp.mean(x.astype(jnp.float32), 0).astype(x.dtype), stacked)
    if rnd.wire.codec is not None and rnd.wire.mode == "legacy":
        mean, _ = compress_tree(rnd.wire.codec, mean, key_t)
    return mean


# --------------------------------------------------------------------------- #
# the reference executor
# --------------------------------------------------------------------------- #
class RoundExecutor:
    """Runs a ``RoundProgram`` one round at a time, per worker.

    ``run(t, params, state, batch, workers=..., views=...)`` executes one
    scheduled round over an arbitrary subset of workers (``workers``, the
    live membership — default all ``m``), optionally feeding each worker its
    own stale model view (``views``: worker -> params, the simulator's
    bounded-staleness replay).  Locals are evaluated under one jitted vmap
    when every worker shares the current model; divergent views fall back to
    per-worker calls of the same jitted local.

    Byte accounting: the round's wire bytes land in
    ``metrics["comm_bytes"]`` AND are booked via ``dist.collectives.note``
    (a no-op outside a ``CommLedger.wrap``), so wrapped replays record the
    identical number.
    """

    def __init__(self, prog: RoundProgram):
        self.prog = prog
        self._vmapped: Dict[Any, Callable] = {}
        self._single: Dict[Any, Callable] = {}

    # -- cached jitted pieces ------------------------------------------------ #
    # keyed by the Round OBJECT (identity hash, and a strong reference): the
    # historical ``id(rnd)`` keys let a dynamically built round alias a dead
    # round's id and silently run the wrong jitted local
    # (tests/test_rounds_equivalence.py pins the regression)
    def _vmapped_local(self, rnd: Round, replica_axis: Optional[int]):
        key = (rnd, replica_axis)
        fn = self._vmapped.get(key)
        if fn is None:
            fn = jax.jit(jax.vmap(rnd.local,
                                  in_axes=(None, 0, replica_axis, 0)))
            self._vmapped[key] = fn
        return fn

    def _single_local(self, rnd: Round):
        fn = self._single.get(rnd)
        if fn is None:
            fn = jax.jit(rnd.local)
            self._single[rnd] = fn
        return fn

    # -- one round ----------------------------------------------------------- #
    def run(self, t: int, params: Any, state: Any, batch: Any, *,
            workers: Optional[Sequence[int]] = None,
            views: Optional[Dict[int, Any]] = None,
            key=None) -> Tuple[Any, Any, Dict[str, Any]]:
        prog = self.prog
        step = prog.round_for(t, state)
        rnd, t_step = step.round, step.t_step
        if prog.prepare is not None:
            batch = prog.prepare(t, batch, key)
        tj = jnp.int32(t_step)
        cs = prog.client_sampling
        weights = None

        if cs is not None:
            # federated replay: the live cohort (sampled here unless the
            # caller already drew it), each client on its own identity-keyed
            # shard; the masked-average weights are the client dataset sizes
            from repro.core.federated import cohort_shards
            assert not rnd.replica, \
                "client-sampling rounds keep one server model, not replicas"
            assert views is None, \
                "client-sampling rounds are server-synchronous (no views)"
            ws = list(cs.cohort_for(t)) if workers is None else list(workers)
            assert ws, "a round needs at least one participating worker"
            w_arr = jnp.asarray(ws, jnp.uint32)
            shards_sel = cohort_shards(batch, ws, t, cs)
            payloads, aux = self._vmapped_local(rnd, None)(
                tj, w_arr, params, shards_sel)
            if rnd.collective == "masked_average":
                weights = cs.client_weights(ws)
        else:
            shards = split_shards(batch, prog.m)
            ws = list(range(prog.m)) if workers is None else list(workers)
            assert ws, "a round needs at least one participating worker"
            idx = jnp.asarray(ws, jnp.int32)
            w_arr = jnp.asarray(ws, jnp.uint32)
            shards_sel = _slice_tree(shards, idx)

            if rnd.replica:
                models = _slice_tree(state["replicas"], idx)
                payloads, aux = self._vmapped_local(rnd, 0)(
                    tj, w_arr, models, shards_sel)
            elif views is None:
                payloads, aux = self._vmapped_local(rnd, None)(
                    tj, w_arr, params, shards_sel)
            else:
                single = self._single_local(rnd)
                outs = [single(tj, jnp.uint32(w), views.get(w, params),
                               _slice_tree(shards, w)) for w in ws]
                payloads = _stack_trees([p for p, _ in outs])
                aux = jnp.stack([a for _, a in outs])

        one = _slice_tree(payloads, 0)
        nbytes = wire_nbytes(rnd, one, len(ws))
        reduced = reduce_payloads(rnd, payloads, ws,
                                  _wire_key(rnd.wire, key, t_step),
                                  weights=weights)
        if nbytes:
            coll.note(rnd.collective, None, nbytes=nbytes, tag=rnd.tag)
        if aux is not None:
            coll.note("pmean", jnp.zeros((), jnp.float32), tag="loss",
                      payload=False)

        params, state, metrics = rnd.apply(tj, params, state, reduced,
                                           w_arr, aux)
        if step.host_updates:
            state = {**state, **step.host_updates}
        metrics = dict(metrics)
        metrics.setdefault("order", rnd.order)
        metrics["comm_bytes"] = nbytes
        metrics["n_live"] = len(ws)
        return params, state, metrics


def to_method(prog: RoundProgram) -> Method:
    """Adapt a ``RoundProgram`` to the uniform ``Method`` interface: the
    step runs the scheduled round over all ``m`` workers through a
    ``RoundExecutor`` (the single-host reference execution)."""
    ex = RoundExecutor(prog)

    def step(t, params, state, batch, key=None):
        return ex.run(t, params, state, batch, key=key)

    return Method(prog.name, prog.init, step, prog.comm_scalars, prog.fevals,
                  prog.gevals, program=prog)


# --------------------------------------------------------------------------- #
# the HO-SGD family as a round program
# --------------------------------------------------------------------------- #
def fo_round(loss_fn: Callable, opt, *, wire: Optional[Wire] = None,
             overlap: Optional[Overlap] = None) -> Round:
    """Eq. (3): each worker's shard gradient, all-reduce mean, optimizer
    update.  The mesh lowering (``core.distributed.make_fo_step``) fuses the
    per-worker locals into one data-parallel ``value_and_grad`` whose
    gradient all-reduce GSPMD inserts — same math, booked identically.
    An ``overlap`` spec buckets the gradient all-reduce (chunked lowering on
    the mesh, exposed-comm pricing in the sim) without changing bytes."""
    from repro.opt.optimizers import apply_deltas

    wire = wire or Wire()

    def local(t, worker, model, shard):
        loss, grads = jax.value_and_grad(loss_fn)(model, shard)
        return grads, loss

    @jax.jit
    def _apply_j(t, params, opt_state, grads, f_mean):
        deltas, opt_state = opt.update(grads, opt_state, params, t)
        return apply_deltas(params, deltas), opt_state, f_mean

    def apply(t, params, state, reduced, workers, aux):
        params, opt_state, loss = _apply_j(t, params, state["opt"], reduced,
                                           jnp.mean(aux))
        return params, {**state, "opt": opt_state}, {"loss": loss}

    return Round("fo", 1, "all_reduce", local, apply, wire=wire,
                 meta={"loss_fn": loss_fn, "opt": opt},
                 overlap=overlap or Overlap())


def zo_round(loss_fn: Callable, ho, opt, *, m: Optional[int] = None,
             overlap: Optional[Overlap] = None) -> Round:
    """Eq. (4)-(6): each worker's directional-derivative scalar in its
    pre-shared direction, all-gathered; every receiver reconstructs the
    update from the coefficients of the workers that actually contributed
    (``workers`` — the live membership divides the estimate, not the nominal
    ``m``)."""
    from repro.core.engine import make_engine
    from repro.opt.optimizers import apply_deltas

    def local(t, worker, model, shard):
        eng = make_engine(ho.engine, model, ho.seed, acc_dtype=ho.acc_dtype)
        c, f0 = eng.zo_coeff(loss_fn, model, shard, t, worker, ho.mu)
        return c, f0

    @jax.jit
    def _apply_j(t, params, opt_state, coeffs, workers, f0s):
        eng = make_engine(ho.engine, params, ho.seed, acc_dtype=ho.acc_dtype)
        k = int(coeffs.shape[0])
        rec = eng.reconstruct(coeffs, t, workers)
        g_hat = jax.tree.map(lambda a: a * (ho.zo_scale / k), rec)
        deltas, opt_state = opt.update(g_hat, opt_state, params, t)
        return apply_deltas(params, deltas), opt_state, jnp.mean(f0s)

    def apply(t, params, state, reduced, workers, aux):
        params, opt_state, loss = _apply_j(t, params, state["opt"], reduced,
                                           workers, aux)
        return params, {**state, "opt": opt_state}, {"loss": loss}

    return Round("zo", 0, "all_gather", local, apply,
                 meta={"loss_fn": loss_fn, "ho": ho, "opt": opt, "m": m},
                 overlap=overlap or Overlap())


def ho_sgd_program(
    loss_fn: Callable,
    ho,
    opt=None,
    *,
    name: str = "ho_sgd",
    wire: Optional[Wire] = None,
    tau_schedule: Optional[Callable[[int], int]] = None,
    zo_only: bool = False,
    overlap: Optional[Overlap] = None,
    client_sampling: Any = None,
) -> RoundProgram:
    """HO-SGD (Algorithm 1) as a round program: FO sync rounds every tau
    iterations (or per ``tau_schedule`` through the shared
    ``adaptive_tau_decision``), ZO rounds in between; ``zo_only`` never
    syncs (distributed ZO-SGD).  State is ``{"opt": ..., "since_fo": int}``
    — the same layout the simulator checkpoints.  ``overlap`` buckets both
    round kinds' collectives (time only, never bytes).

    ``client_sampling`` (``core.federated.ClientSampling``, cohort_k must
    equal ``ho.m``) makes the program federated: every round runs over a
    freshly sampled client cohort on identity-keyed shards.  The ZO
    direction streams survive sampling unchanged — they were always keyed
    on worker IDENTITY, so client 812's direction at round t does not
    depend on who else was sampled."""
    from repro.core.ho_sgd import adaptive_tau_decision
    from repro.opt.optimizers import const_schedule, sgd

    opt = opt or sgd(const_schedule(ho.lr), ho.momentum)
    fo = fo_round(loss_fn, opt, wire=wire, overlap=overlap)
    zo = zo_round(loss_fn, ho, opt, m=ho.m, overlap=overlap)

    def init(params):
        return {"opt": opt.init(params), "since_fo": 0}

    def round_for(t: int, state) -> RoundStep:
        if zo_only:
            return RoundStep(zo, t, {"since_fo": int(state["since_fo"]) + 1})
        if tau_schedule is not None:
            is_fo, t_step, since = adaptive_tau_decision(
                t, int(state["since_fo"]), tau_schedule(t), ho.tau)
            return RoundStep(fo if is_fo else zo, t_step, {"since_fo": since})
        is_fo = t % ho.tau == 0
        since = 0 if is_fo else int(state["since_fo"]) + 1
        return RoundStep(fo if is_fo else zo, t, {"since_fo": since})

    tau = max(1, ho.tau)
    return RoundProgram(
        name, ho.m, init, round_for,
        comm_scalars=lambda d: (d + (tau - 1)) / tau,
        fevals=lambda d: 2.0 * (tau - 1) / tau,
        gevals=lambda d: 1.0 / tau,
        client_sampling=client_sampling,
    )
