"""HO-SGD: the paper's contribution (Algorithm 1) and its baselines."""
from repro.core.ho_sgd import (  # noqa: F401
    HOSGDConfig,
    Method,
    adaptive_tau_decision,
    make_adaptive_ho_sgd,
    make_ho_sgd,
    make_sync_sgd,
    make_zo_sgd,
    parse_tau_schedule,
    run_method,
)
from repro.core.baselines import (  # noqa: F401
    make_gossip_pa_sgd,
    make_pa_sgd,
    make_qsgd,
    make_ri_sgd,
    make_zo_svrg_ave,
)
from repro.core.rounds import (  # noqa: F401
    Round,
    RoundExecutor,
    RoundProgram,
    RoundStep,
    Wire,
    ho_sgd_program,
    masked_average,
    to_method,
)
from repro.core.federated import (  # noqa: F401
    ClientSampling,
    cohort_shards,
    fed_avg_program,
)
