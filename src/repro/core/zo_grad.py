"""Zeroth-order stochastic gradient estimation (Algorithm 1, eq. (4)).

``G_mu(x, zeta, v) = (d/mu) * [F(x + mu*v, zeta) - F(x, zeta)] * v``

computed with exactly two function evaluations per worker per iteration.
Only the *scalar* coefficient ``c = (d/mu)*(F(x+mu*v) - F(x))`` needs to be
communicated; the vector is regenerated from the pre-shared seed.
"""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

from repro.core import directions as D
from repro.core.engine import make_engine


def zo_coefficient(
    loss_fn: Callable[[Any, Any], jax.Array],
    params: Any,
    batch: Any,
    v_tree: Any,
    mu: float,
    dim: int,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (c, f0): the scalar ZO coefficient and the base loss value."""
    f0 = loss_fn(params, batch)
    f1 = loss_fn(D.tree_axpy(jnp.float32(mu), v_tree, params), batch)
    c = (dim / mu) * (f1 - f0)
    return c.astype(jnp.float32), f0


def zo_gradient(
    loss_fn: Callable,
    params: Any,
    batch: Any,
    seed: int,
    t,
    worker,
    mu: float,
    engine: str = "tree",
) -> Tuple[Any, jax.Array, jax.Array]:
    """Full single-worker ZO gradient estimate (c * v), plus (c, f0)."""
    eng = make_engine(engine, params, seed)
    worker = jnp.asarray(worker, jnp.uint32)
    c, f0 = eng.zo_coeff(loss_fn, params, batch, t, worker, mu)
    g = eng.reconstruct(c.reshape(1), t, workers=worker.reshape(1))
    return g, c, f0


def reconstruct_update(params: Any, coeffs: jax.Array, seed: int, t,
                       engine: str = "tree", vmap_workers: bool = False) -> Any:
    """(1/m) * sum_i c_i * v_{t,i} regenerated locally from the scalars.

    ``coeffs`` is the all-gathered (m,) vector of scalar coefficients.  The
    ``tree`` backend unrolls the worker loop (m is a static mesh property)
    so the lowered HLO has no extra while-loop — keeps the roofline
    scan-correction simple; ``vmap_workers`` generates the m directions
    under one vmap instead (HLO O(1) in m for large-m CPU rehearsals).
    """
    eng = make_engine(engine, params, seed)
    rec = eng.reconstruct(coeffs, t, vmap_workers=vmap_workers)
    return jax.tree.map(lambda a: a / coeffs.shape[0], rec)


def smoothed_loss(loss_fn: Callable, params: Any, batch: Any, mu: float,
                  key, n_samples: int = 64) -> jax.Array:
    """Monte-Carlo estimate of f_mu(x) = E_u[f(x + mu*u)] (Definition 1).

    Used by property tests to check the estimator's (near-)unbiasedness for
    the smoothing function's gradient.
    """
    def one(k):
        u = jax.tree.map(lambda p: jax.random.normal(k, p.shape), params)
        ssq = sum(jnp.sum(jnp.square(x)) for x in jax.tree.leaves(u))
        u = jax.tree.map(lambda x: x * jax.lax.rsqrt(ssq), u)
        # uniform in the ball: scale by r^(1/d) with r ~ U(0,1)
        dim = D.tree_dim(params)
        r = jax.random.uniform(jax.random.fold_in(k, 1)) ** (1.0 / dim)
        return loss_fn(D.tree_axpy(mu * r, u, params), batch)

    keys = jax.random.split(key, n_samples)
    return jnp.mean(jax.vmap(one)(keys))
