"""Pluggable hardware cost models: compute time from FLOP counts, link time
from an alpha–beta model.

The compute side prices the two oracle kinds the paper distinguishes — a
first-order gradient (forward + backward) and a zeroth-order function
evaluation — from per-problem FLOP counts, so an iteration's time is
``(fevals + ratio * gevals) * fwd_flops / flops_per_sec``.  The counts per
iteration come from the replayed ``Method``'s analytic cost model
(``Method.fevals`` / ``Method.gevals``, resolved per step order by the
runner), never re-invented here.

The communication side is the classic alpha–beta model: a collective moving
``nbytes`` (per worker, the ``CommLedger`` receive convention) costs
``alpha + nbytes / bandwidth``.  Byte counts are NOT computed in this module
— the runner reads them from the ``CommLedger`` of the replayed step
programs, or from ``repro.dist.compress`` wire estimates (see
``repro.sim.runner``), so the simulator can never drift from what the real
steps book.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Optional


@dataclass(frozen=True)
class LinkModel:
    """alpha–beta link: ``time(n) = alpha + n * beta`` (beta = 1/bandwidth)."""

    alpha: float          # per-collective latency, seconds
    beta: float           # seconds per byte

    def time(self, nbytes: float) -> float:
        if nbytes <= 0:
            return 0.0
        return self.alpha + float(nbytes) * self.beta


#: recognized all-reduce algorithms (``CollectiveModel.kind``)
COLLECTIVE_KINDS = ("flat", "ring", "tree", "gossip")


def ring_all_reduce_time(link: LinkModel, nbytes: float, w: int) -> float:
    """Ring all-reduce over ``w`` workers: reduce-scatter + all-gather,
    ``2(w-1)`` rounds each moving ``nbytes / w``:
    ``2(w-1)·alpha + 2(w-1)/w · nbytes·beta``."""
    if nbytes <= 0 or w <= 1:
        return 0.0
    return 2.0 * (w - 1) * link.alpha + (2.0 * (w - 1) / w) * float(nbytes) * link.beta


def tree_all_reduce_time(link: LinkModel, nbytes: float, w: int) -> float:
    """Binary-tree all-reduce: ``ceil(log2(w))`` reduce rounds up the tree
    plus the same number of broadcast rounds down, each moving the full
    buffer: ``2·log2(w) · (alpha + nbytes·beta)``."""
    if nbytes <= 0 or w <= 1:
        return 0.0
    rounds = 2.0 * math.ceil(math.log2(w))
    return rounds * (link.alpha + float(nbytes) * link.beta)


def flat_all_reduce_time(link: LinkModel, nbytes: float, w: int) -> float:
    """The PR-3 model: one fully-switched exchange, latency and wire time
    independent of ``w`` (every worker receives ``nbytes`` at once)."""
    if w <= 1:
        return 0.0
    return link.time(nbytes)


def gossip_exchange_time(link: LinkModel, nbytes: float, w: int) -> float:
    """One ring-gossip round (the ``neighbor_exchange`` collective of the
    round IR): every worker receives its ring neighbors' payloads —
    ``min(2, w-1)`` sequential transfers of ``nbytes`` each:
    ``k·(alpha + nbytes·beta)``, independent of the ring length beyond the
    two-neighbor degree (the decentralized scaling win)."""
    if nbytes <= 0 or w <= 1:
        return 0.0
    k = min(2, w - 1)
    return k * (link.alpha + float(nbytes) * link.beta)


_ALGOS = {"flat": flat_all_reduce_time, "ring": ring_all_reduce_time,
          "tree": tree_all_reduce_time, "gossip": gossip_exchange_time}


@dataclass(frozen=True)
class CollectiveModel:
    """Prices one all-reduce of ``nbytes`` (per worker, the ``CommLedger``
    receive convention) over ``w`` participating workers.

    ``kind`` selects the single-link algorithm (``flat`` — PR 3's switched
    exchange; ``ring``; ``tree``).  With ``pods > 1`` the reduce is
    hierarchical: the ``kind`` algorithm runs intra-pod over
    ``ceil(w / pods)`` workers on ``link``, then a ring exchange runs
    inter-pod over ``pods`` on ``inter_link`` (the Topology's slow link).
    ``w`` is the CURRENT membership — elastic clusters shrink/grow it and
    the round structure reprices accordingly, while byte counts stay
    whatever the replayed programs booked.
    """

    link: LinkModel
    kind: str = "flat"
    pods: int = 1
    inter_link: Optional[LinkModel] = None

    def __post_init__(self):
        assert self.kind in COLLECTIVE_KINDS, \
            f"unknown collective {self.kind!r}; have {COLLECTIVE_KINDS}"
        assert self.pods >= 1
        if self.pods > 1:
            assert self.inter_link is not None, \
                "multi-pod collectives need an inter-pod LinkModel"

    def all_reduce_time(self, nbytes: float, w: int) -> float:
        intra, inter = self.time_components(nbytes, w)
        return intra + inter

    def time_components(self, nbytes: float, w: int) -> "tuple[float, float]":
        """``(intra_pod_s, inter_pod_s)`` split of the collective's time —
        the contention model routes each component through its own shared
        link (the pod link vs the Topology's inter-pod link).  Single-pod
        collectives put everything in the intra component."""
        if nbytes <= 0 or w <= 1:
            return 0.0, 0.0
        algo = _ALGOS[self.kind]
        if self.pods <= 1:
            return algo(self.link, nbytes, w), 0.0
        wpp = max(1, math.ceil(w / self.pods))
        intra = algo(self.link, nbytes, wpp)
        inter = ring_all_reduce_time(self.inter_link, nbytes,
                                     min(self.pods, w))
        return intra, inter


def exposed_comm_time(cm: CollectiveModel, nbytes: float, w: int,
                      buckets: int, compute_s: float) -> float:
    """Exposed (critical-path) communication time of one overlapped round.

    With the payload split into ``buckets`` buckets, bucket k's collective
    pipelines behind the compute producing chunk k+1, so only
    ``max(0, comm − overlappable)`` of the collective's time lands on the
    critical path, where ``overlappable = compute · (B−1)/B`` — the first
    chunk must finish before the first bucket can depart, so 1/B of the
    round's compute can never hide traffic.  This is the optimistic
    pipelining bound: bucket latencies are assumed hidden inside the
    pipeline, and ``comm`` is the full payload's collective time (bytes are
    unchanged by bucketing — the ``CommLedger`` invariant).

    ``buckets=1`` degenerates exactly to the strict compute-then-communicate
    price (``comm`` fully exposed), keeping every unbucketed pin intact.
    """
    comm = cm.all_reduce_time(nbytes, w)
    if buckets <= 1 or comm <= 0.0:
        return comm
    overlappable = float(compute_s) * (buckets - 1) / buckets
    return max(0.0, comm - overlappable)


def overlapped_step_time(cm: CollectiveModel, nbytes: float, w: int,
                         buckets: int, compute_s: float) -> float:
    """Critical-path time of one overlapped round: local compute plus the
    exposed tail of its bucketed collective."""
    return float(compute_s) + exposed_comm_time(cm, nbytes, w, buckets,
                                                compute_s)


@dataclass(frozen=True)
class ComputeModel:
    """Prices oracle calls on one worker's batch shard.

    ``fwd_flops`` is the FLOP count of ONE loss evaluation on one worker's
    shard; a gradient evaluation (forward + backward) costs
    ``fwd_bwd_ratio`` times that (3.0 is the standard dense-matmul
    estimate: backward ≈ 2× forward).
    """

    fwd_flops: float
    flops_per_sec: float
    fwd_bwd_ratio: float = 3.0

    def flops(self, fevals: float, gevals: float) -> float:
        return (fevals + self.fwd_bwd_ratio * gevals) * self.fwd_flops

    def time(self, fevals: float, gevals: float, speed: float = 1.0) -> float:
        return self.flops(fevals, gevals) / (self.flops_per_sec * speed)


@dataclass(frozen=True)
class StepCost:
    """One iteration's priced quantities (per worker)."""

    fevals: float         # zeroth-order oracle calls
    gevals: float         # first-order oracle calls
    comm_bytes: int       # wire bytes per worker (0 = no collective)


def tree_fwd_flops(params_like: Any, per_worker_batch: int) -> float:
    """Dense estimate for an arbitrary parameter tree: 2 FLOPs per parameter
    per sample (one multiply-add per weight — exact for the MLP/quadratic
    problems the sim tests replay)."""
    import jax

    d = sum(int(x.size) for x in jax.tree.leaves(params_like))
    return 2.0 * d * per_worker_batch


def config_fwd_flops(cfg: Any, per_worker_batch: int, seq: int) -> float:
    """Transformer estimate from a ``ModelConfig``: 2 * active params per
    token (the standard decoder FLOP model; attention's quadratic term is
    below the matmul term at the seq lengths the sim rehearses)."""
    return 2.0 * cfg.param_count(active_only=True) * per_worker_batch * seq


def per_order_step_costs(fevals: float, gevals: float, comm_bytes: int) -> StepCost:
    """Convenience constructor kept for symmetry with the runner factories."""
    return StepCost(float(fevals), float(gevals), int(comm_bytes))


def validate_against_method(method, d: int, costs_by_order, order_mix) -> None:
    """Cross-check: per-order eval counts, amortized over the order mix,
    must reproduce the Method's analytic per-iteration counters.

    ``order_mix`` maps order -> fraction of iterations; used by tests so a
    runner-constructed cost table can never drift from ``Method.fevals`` /
    ``Method.gevals``.
    """
    fe = sum(order_mix[o] * costs_by_order[o].fevals for o in order_mix)
    ge = sum(order_mix[o] * costs_by_order[o].gevals for o in order_mix)
    assert math.isclose(fe, method.fevals(d), rel_tol=1e-9, abs_tol=1e-12), \
        f"fevals drift: per-order {fe} vs analytic {method.fevals(d)}"
    assert math.isclose(ge, method.gevals(d), rel_tol=1e-9, abs_tol=1e-12), \
        f"gevals drift: per-order {ge} vs analytic {method.gevals(d)}"
