"""ClusterSpec: the simulated hardware — worker speeds, links, failures.

One frozen dataclass describes everything stochastic or hardware-shaped
about a simulated cluster; the spec's ``seed`` drives every draw (straggler
slowdowns, jitter, failure arrivals), so the determinism contract is simply:
same ``ClusterSpec`` (including seed) + same replayed method ⇒ identical
event trace.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Tuple

import numpy as np

from repro.sim.costs import LinkModel


@dataclass(frozen=True)
class ClusterSpec:
    """Hardware + fault model for one simulated cluster.

    Compute: ``flops_per_sec`` is the base per-worker throughput;
    ``rel_speeds`` (len m, default homogeneous) models persistent
    heterogeneity, while stragglers/jitter are per-(iteration, worker)
    draws: with probability ``straggler_prob`` a worker's iteration takes
    ``straggler_slowdown`` times longer, and ``jitter_sigma`` adds
    lognormal multiplicative noise on top.

    Failures: a Poisson process at ``fail_rate`` failures per simulated
    second (cluster-wide).  A failure kills the in-flight iteration; the
    cluster restores the last checkpoint written every ``ckpt_every``
    iterations (a REAL ``repro.checkpoint`` round-trip in the runner) and
    pays ``restart_time`` simulated seconds before resuming.
    """

    m: int = 4
    flops_per_sec: float = 1e12
    rel_speeds: Tuple[float, ...] = ()
    alpha: float = 1e-4                  # link latency per collective (s)
    bandwidth: float = 1e9               # bytes/s per worker
    straggler_prob: float = 0.0
    straggler_slowdown: float = 4.0
    jitter_sigma: float = 0.0
    fail_rate: float = 0.0               # failures per simulated second
    restart_time: float = 30.0           # checkpoint-restore charge (s)
    ckpt_every: int = 0                  # iterations between sim checkpoints
    seed: int = 0

    def __post_init__(self):
        assert self.m >= 1
        assert self.bandwidth > 0 and self.flops_per_sec > 0
        if self.rel_speeds:
            assert len(self.rel_speeds) == self.m, \
                f"{len(self.rel_speeds)} rel_speeds for m={self.m}"
            assert all(s > 0 for s in self.rel_speeds)
        if self.fail_rate > 0:
            assert self.ckpt_every > 0, \
                "failure injection needs ckpt_every > 0 (restore source)"

    # ---- derived models ---------------------------------------------------- #
    @property
    def link(self) -> LinkModel:
        return LinkModel(alpha=self.alpha, beta=1.0 / self.bandwidth)

    def speeds(self) -> Tuple[float, ...]:
        return self.rel_speeds if self.rel_speeds else (1.0,) * self.m

    def with_(self, **kw) -> "ClusterSpec":
        return replace(self, **kw)

    # ---- seeded draws (all randomness enters the sim here) ----------------- #
    def rng(self) -> np.random.Generator:
        return np.random.default_rng(self.seed)

    def draw_slowdowns(self, rng: np.random.Generator) -> np.ndarray:
        """(m,) multiplicative time factors for one iteration — combines the
        persistent ``rel_speeds`` with this iteration's straggler/jitter
        draws.  Draw order is fixed (jitter, then stragglers, workers in
        index order) so the trace is reproducible."""
        mult = np.ones(self.m)
        if self.jitter_sigma > 0:
            mult *= rng.lognormal(0.0, self.jitter_sigma, self.m)
        if self.straggler_prob > 0:
            hit = rng.random(self.m) < self.straggler_prob
            mult = np.where(hit, mult * self.straggler_slowdown, mult)
        return mult / np.asarray(self.speeds())

    def draw_failure_gap(self, rng: np.random.Generator) -> float:
        """Seconds until the next failure (inf when failures are off)."""
        if self.fail_rate <= 0:
            return math.inf
        return float(rng.exponential(1.0 / self.fail_rate))


def bandwidth_constrained(m: int = 4, *, seed: int = 0,
                          bandwidth: float = 1e5,
                          alpha: float = 1e-5,
                          flops_per_sec: float = 1e9) -> ClusterSpec:
    """The paper's target regime: links are the bottleneck, compute is not.

    A d-dim fp32 all-reduce costs ``4*d/bandwidth`` — orders of magnitude
    above both the per-collective latency and a function evaluation — which
    is exactly when amortizing FO exchanges over tau ZO iterations pays."""
    return ClusterSpec(m=m, flops_per_sec=flops_per_sec, alpha=alpha,
                       bandwidth=bandwidth, seed=seed)
