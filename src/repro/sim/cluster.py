"""ClusterSpec: the simulated hardware — worker speeds, links, failures.

One frozen dataclass describes everything stochastic or hardware-shaped
about a simulated cluster; the spec's ``seed`` drives every draw (straggler
slowdowns, jitter, failure arrivals), so the determinism contract is simply:
same ``ClusterSpec`` (including seed) + same replayed method ⇒ identical
event trace.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Optional, Tuple

import numpy as np

from repro.sim.costs import COLLECTIVE_KINDS, CollectiveModel, LinkModel


@dataclass(frozen=True)
class Topology:
    """Multi-pod link topology: ``pods`` × workers-per-pod, mirroring the
    ``("pod", "data")`` mesh axes ``repro.dist.sharding`` owns.

    Workers inside a pod talk over the ``ClusterSpec``'s (fast) link; pods
    talk over this (slow) inter-pod link.  A hierarchical all-reduce is
    priced as the selected intra-pod algorithm over ``m / pods`` workers
    plus an inter-pod ring exchange over ``pods`` (see
    ``costs.CollectiveModel``).
    """

    pods: int = 1
    inter_alpha: float = 1e-3            # inter-pod latency per collective (s)
    inter_bandwidth: float = 1e8         # inter-pod bytes/s per worker

    def __post_init__(self):
        assert self.pods >= 1
        assert self.inter_bandwidth > 0 and self.inter_alpha >= 0

    @property
    def inter_link(self) -> LinkModel:
        return LinkModel(alpha=self.inter_alpha,
                         beta=1.0 / self.inter_bandwidth)

    def workers_per_pod(self, m: int) -> int:
        """Workers in the fullest pod — a CEIL split, priced exactly like
        ``CollectiveModel.time_components``: sampled federated cohorts and
        shrunken elastic memberships are not pod-divisible, and the fullest
        pod bounds the hierarchical reduce's intra-pod stage."""
        return max(1, math.ceil(m / self.pods))


@dataclass(frozen=True)
class ClusterSpec:
    """Hardware + fault model for one simulated cluster.

    Compute: ``flops_per_sec`` is the base per-worker throughput;
    ``rel_speeds`` (len m, default homogeneous) models persistent
    heterogeneity, while stragglers/jitter are per-(iteration, worker)
    draws: with probability ``straggler_prob`` a worker's iteration takes
    ``straggler_slowdown`` times longer, and ``jitter_sigma`` adds
    lognormal multiplicative noise on top.

    Failures: a Poisson process at ``fail_rate`` failures per simulated
    second (cluster-wide).  In the default (bulk-synchronous) mode a
    failure kills the in-flight iteration; the cluster restores the last
    checkpoint written every ``ckpt_every`` iterations (a REAL
    ``repro.checkpoint`` round-trip in the runner) and pays
    ``restart_time`` simulated seconds before resuming.  With
    ``elastic=True`` a failure instead REMOVES the victim from the
    membership (no rollback): the survivors keep iterating with the
    collective priced at the shrunken ``W``, and the victim rejoins after
    a seeded exponential downtime (mean ``downtime`` seconds) through a
    real checkpoint round-trip, growing ``W`` back.

    Execution: ``max_staleness = 0`` is bulk-synchronous (every iteration
    barriers).  ``max_staleness = s > 0`` lets workers run ZO iterations
    WITHOUT the barrier, each at most ``s`` rounds ahead of the slowest
    worker's committed round; FO sync rounds always barrier, matching
    HO-SGD's semantics (the tau-th exchange is the consistency point).

    Federated (``n_clients > 0``): the cluster's ``m`` worker slots hold a
    sampled cohort of ``cohort_k`` (= m) of ``n_clients`` clients, redrawn
    every round from the ``sampling`` spec (seeded by the cluster seed) with
    per-client ``availability`` churn; the runner prices each round's
    collective at the LIVE cohort size and the trajectory genuinely follows
    the sample.  Federated rounds are server-synchronous — ``max_staleness``
    and ``elastic`` must stay off (churn is the availability mask).

    Links: ``collective`` picks the all-reduce algorithm (``flat`` —
    PR 3's switched exchange — ``ring`` or ``tree``); a ``topology`` with
    ``pods > 1`` makes the reduce hierarchical (intra-pod ``collective``
    + inter-pod ring on the topology's slow link).  With ``contention``
    (the latency-honest default) the UNBARRIERED exchanges of async rounds
    route through shared per-pod links plus the inter-pod link
    (``events.LinkContention``): concurrent transfers serialize in
    deterministic (time, worker) order instead of being priced
    independently.  Barriered collectives are unaffected — the
    ``CollectiveModel`` already prices the joint algorithm and nothing else
    is in flight at a barrier — so synchronous specs are bit-identical
    with the flag on or off.
    """

    m: int = 4
    flops_per_sec: float = 1e12
    rel_speeds: Tuple[float, ...] = ()
    alpha: float = 1e-4                  # link latency per collective (s)
    bandwidth: float = 1e9               # bytes/s per worker
    collective: str = "flat"             # all-reduce algorithm (costs.py)
    topology: Optional[Topology] = None  # multi-pod links (None = one pod)
    max_staleness: int = 0               # 0 = bulk-synchronous ZO rounds
    straggler_prob: float = 0.0
    straggler_slowdown: float = 4.0
    jitter_sigma: float = 0.0
    fail_rate: float = 0.0               # failures per simulated second
    elastic: bool = False                # failures shrink W instead of rollback
    downtime: float = 60.0               # mean elastic rejoin delay (s)
    restart_time: float = 30.0           # checkpoint-restore charge (s)
    ckpt_every: int = 0                  # iterations between sim checkpoints
    contention: bool = True              # shared links for async exchanges
    n_clients: int = 0                   # >0: federated client population N
    cohort_k: int = 0                    # sampled clients per round (= m)
    availability: float = 1.0            # per-round client up-probability
    seed: int = 0

    def __post_init__(self):
        assert self.m >= 1
        assert self.bandwidth > 0 and self.flops_per_sec > 0
        assert self.collective in COLLECTIVE_KINDS, \
            f"unknown collective {self.collective!r}; have {COLLECTIVE_KINDS}"
        assert self.max_staleness >= 0
        assert self.downtime > 0
        if self.rel_speeds:
            assert len(self.rel_speeds) == self.m, \
                f"{len(self.rel_speeds)} rel_speeds for m={self.m}"
            assert all(s > 0 for s in self.rel_speeds)
        if self.elastic:
            assert self.m >= 2, "elastic membership needs m >= 2"
        if self.fail_rate > 0 and not self.elastic:
            assert self.ckpt_every > 0, \
                "failure injection needs ckpt_every > 0 (restore source)"
        assert 0.0 < self.availability <= 1.0, \
            f"availability must be in (0, 1], got {self.availability}"
        if self.n_clients > 0:
            assert 1 <= self.cohort_k <= self.n_clients, (
                f"cohort_k={self.cohort_k} not in "
                f"[1, n_clients={self.n_clients}]")
            assert self.cohort_k == self.m, (
                f"federated spec: m={self.m} must equal "
                f"cohort_k={self.cohort_k} — the sim's worker slots hold "
                f"the sampled cohort")
            assert self.max_staleness == 0 and not self.elastic, \
                "federated rounds are server-synchronous: no staleness, " \
                "no elastic membership (churn comes from availability)"
        else:
            assert self.cohort_k == 0, \
                "cohort_k without n_clients — set both or neither"

    # ---- derived models ---------------------------------------------------- #
    @property
    def link(self) -> LinkModel:
        return LinkModel(alpha=self.alpha, beta=1.0 / self.bandwidth)

    @property
    def collective_model(self) -> CollectiveModel:
        topo = self.topology
        return CollectiveModel(
            link=self.link, kind=self.collective,
            pods=topo.pods if topo is not None else 1,
            inter_link=topo.inter_link if topo is not None else None)

    def collective_time(self, nbytes: float, w: Optional[int] = None) -> float:
        """Time of one all-reduce of ``nbytes`` over ``w`` workers (defaults
        to the full membership ``m``; elastic runs pass the live count)."""
        return self.collective_model.all_reduce_time(
            nbytes, self.m if w is None else w)

    @property
    def sampling(self):
        """The ``core.federated.ClientSampling`` spec of a federated
        cluster (``n_clients > 0``), seeded by the cluster seed — the ONE
        cohort schedule the round executor and the replay both draw from.
        None on a conventional (always-on) cluster."""
        if self.n_clients <= 0:
            return None
        from repro.core.federated import ClientSampling
        return ClientSampling(self.n_clients, self.cohort_k, seed=self.seed,
                              availability=self.availability)

    def speeds(self) -> Tuple[float, ...]:
        return self.rel_speeds if self.rel_speeds else (1.0,) * self.m

    def with_(self, **kw) -> "ClusterSpec":
        return replace(self, **kw)

    # ---- seeded draws (all randomness enters the sim here) ----------------- #
    def rng(self) -> np.random.Generator:
        return np.random.default_rng(self.seed)

    def draw_slowdowns(self, rng: np.random.Generator) -> np.ndarray:
        """(m,) multiplicative time factors for one iteration — combines the
        persistent ``rel_speeds`` with this iteration's straggler/jitter
        draws.  Draw order is fixed (jitter, then stragglers, workers in
        index order) so the trace is reproducible."""
        mult = np.ones(self.m)
        if self.jitter_sigma > 0:
            mult *= rng.lognormal(0.0, self.jitter_sigma, self.m)
        if self.straggler_prob > 0:
            hit = rng.random(self.m) < self.straggler_prob
            mult = np.where(hit, mult * self.straggler_slowdown, mult)
        return mult / np.asarray(self.speeds())

    def draw_failure_gap(self, rng: np.random.Generator) -> float:
        """Seconds until the next failure (inf when failures are off)."""
        if self.fail_rate <= 0:
            return math.inf
        return float(rng.exponential(1.0 / self.fail_rate))

    def draw_downtime(self, rng: np.random.Generator) -> float:
        """Seconds an elastically-failed worker stays out of the membership
        (seeded exponential with mean ``downtime``)."""
        return float(rng.exponential(self.downtime))


def bandwidth_constrained(m: int = 4, *, seed: int = 0,
                          bandwidth: float = 1e5,
                          alpha: float = 1e-5,
                          flops_per_sec: float = 1e9,
                          **kw) -> ClusterSpec:
    """The paper's target regime: links are the bottleneck, compute is not.

    A d-dim fp32 all-reduce costs ``4*d/bandwidth`` — orders of magnitude
    above both the per-collective latency and a function evaluation — which
    is exactly when amortizing FO exchanges over tau ZO iterations pays.
    Extra ``kw`` pass through to ``ClusterSpec`` (collective, topology,
    max_staleness, elastic, ...)."""
    return ClusterSpec(m=m, flops_per_sec=flops_per_sec, alpha=alpha,
                       bandwidth=bandwidth, seed=seed, **kw)
