"""Deterministic discrete-event core: per-worker clocks + a totally ordered
event trace.

The simulator's determinism guarantee (README §repro.sim) rests entirely on
this module: events are ordered by ``(time, seq)`` where ``seq`` is the
scheduling order, so ties break FIFO and two runs that schedule the same
events in the same order pop them — and record them — identically.  Nothing
here reads wall clocks or global RNG state; all randomness enters through
the seeded draws in ``repro.sim.cluster``.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import List, NamedTuple, Sequence, Tuple


class Event(NamedTuple):
    time: float
    seq: int       # scheduling order — the deterministic tiebreak
    kind: str
    worker: int    # -1 for cluster-wide events


#: what the determinism tests compare: (time, kind, worker) triples in the
#: exact order the loop committed them.
TraceEntry = Tuple[float, str, int]


@dataclass
class EventLoop:
    """Min-heap of future events + the committed trace."""

    _heap: List[Event] = field(default_factory=list)
    _seq: int = 0
    now: float = 0.0
    trace: List[TraceEntry] = field(default_factory=list)

    def schedule(self, at: float, kind: str, worker: int = -1) -> Event:
        assert at >= self.now - 1e-12, f"scheduling into the past: {at} < {self.now}"
        ev = Event(float(at), self._seq, kind, worker)
        self._seq += 1
        heapq.heappush(self._heap, ev)
        return ev

    def pop(self) -> Event:
        """Commit the earliest pending event: advances ``now``, records it."""
        ev = heapq.heappop(self._heap)
        self.now = max(self.now, ev.time)
        self.trace.append((ev.time, ev.kind, ev.worker))
        return ev

    def record(self, at: float, kind: str, worker: int = -1) -> None:
        """Commit an instantaneous event (no heap round-trip)."""
        self.now = max(self.now, float(at))
        self.trace.append((float(at), kind, worker))

    @property
    def pending(self) -> int:
        return len(self._heap)


@dataclass
class WorkerClocks:
    """One simulated clock per worker."""

    t: List[float]

    @classmethod
    def start(cls, m: int, at: float = 0.0) -> "WorkerClocks":
        return cls([float(at)] * m)

    @property
    def m(self) -> int:
        return len(self.t)

    def advance(self, worker: int, dt: float) -> float:
        self.t[worker] += dt
        return self.t[worker]

    def barrier(self) -> float:
        """Synchronize: every clock jumps to the latest — returns that time."""
        sync = max(self.t)
        self.t = [sync] * self.m
        return sync

    def set_all(self, at: float) -> None:
        self.t = [float(at)] * self.m


def barrier_all_reduce(
    loop: EventLoop,
    clocks: WorkerClocks,
    compute_dts: Sequence[float],
    comm_time: float,
    *,
    kind: str = "all_reduce",
) -> float:
    """The simulator's one collective: per-worker compute, barrier, exchange.

    Schedules a ``compute`` completion per worker, drains them through the
    heap (so the trace interleaves workers in global time order), barriers,
    then charges ``comm_time`` once — the bulk-synchronous model every
    method in ``repro.core`` follows.  Returns the completion time, with
    every worker clock advanced to it.  ``comm_time == 0`` records a plain
    ``barrier`` event (an iteration with no exchange, e.g. PA-SGD between
    averaging rounds).
    """
    assert len(compute_dts) == clocks.m
    for i, dt in enumerate(compute_dts):
        loop.schedule(clocks.t[i] + dt, "compute", i)
    for _ in range(clocks.m):
        ev = loop.pop()
        clocks.t[ev.worker] = ev.time
    done = clocks.barrier() + (comm_time if comm_time > 0 else 0.0)
    loop.record(done, kind if comm_time > 0 else "barrier")
    clocks.set_all(done)
    return done
