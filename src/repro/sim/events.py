"""Deterministic discrete-event core: per-worker clocks + a totally ordered
event trace.

The simulator's determinism guarantee (README §repro.sim) rests entirely on
this module: the two collectives (``barrier_all_reduce`` and its
bounded-staleness twin ``async_all_reduce``) commit each round's per-worker
completions in sorted ``(time, worker)`` order — a pure function of clocks
and compute durations, with worker index as the tie-break — so two runs
with the same inputs record identical traces.  (``EventLoop`` also keeps a
``(time, seq)``-ordered heap with FIFO tie-break for callers that schedule
genuinely future events; the collectives commit directly via ``record``
because a fast worker's unbarriered round may legitimately start before a
slower worker's already-committed event.)  Nothing here reads wall clocks
or global RNG state; all randomness enters through the seeded draws in
``repro.sim.cluster``.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import List, NamedTuple, Optional, Sequence, Tuple

from repro.obs.trace import Span, worker_lane


class Event(NamedTuple):
    time: float
    seq: int       # scheduling order — the deterministic tiebreak
    kind: str
    worker: int    # -1 for cluster-wide events


#: what the determinism tests compare: (time, kind, worker) triples in the
#: exact order the loop committed them.  Since the obs layer landed this is
#: a DERIVED VIEW over the committed spans (``EventLoop.trace``), so the
#: determinism suites pin the span path for free.
TraceEntry = Tuple[float, str, int]

#: default span-kind taxonomy for committed legacy event kinds; collective
#: kinds ("all_reduce", "async_exchange", custom) default to comm.exposed
_SPAN_KIND = {
    "compute": "compute",
    "barrier": "barrier",
    "fail": "checkpoint",
    "restore": "checkpoint",
    "leave": "checkpoint",
    "rejoin": "checkpoint",
}


@dataclass
class EventLoop:
    """Min-heap of future events + the committed span list.

    Every commit is a ``repro.obs.trace.Span`` (interval, lane, kind
    taxonomy, ledger bytes); the historical ``(time, kind, worker)`` tuple
    trace is derived from the spans that carry a ``src_kind`` — annotation
    spans (``annotate``) enrich the timeline without entering the tuple
    view, so the bit-identity contract and the Perfetto export read the
    SAME committed events.
    """

    _heap: List[Event] = field(default_factory=list)
    _seq: int = 0
    now: float = 0.0
    spans: List[Span] = field(default_factory=list)

    @property
    def trace(self) -> List[TraceEntry]:
        """The legacy determinism view, derived from the committed spans."""
        return [(s.t1, s.src_kind, s.worker)
                for s in self.spans if s.src_kind is not None]

    def schedule(self, at: float, kind: str, worker: int = -1) -> Event:
        assert at >= self.now - 1e-12, f"scheduling into the past: {at} < {self.now}"
        ev = Event(float(at), self._seq, kind, worker)
        self._seq += 1
        heapq.heappush(self._heap, ev)
        return ev

    def pop(self) -> Event:
        """Commit the earliest pending event: advances ``now``, records it."""
        ev = heapq.heappop(self._heap)
        self.now = max(self.now, ev.time)
        self._commit(ev.time, ev.kind, ev.worker, ev.time, None, 0)
        return ev

    def record(self, at: float, kind: str, worker: int = -1, *,
               t0: Optional[float] = None, span_kind: Optional[str] = None,
               nbytes: int = 0) -> None:
        """Commit an event (no heap round-trip): enters the tuple trace at
        ``at`` with ``kind``, and the span timeline as ``[t0, at]`` (default
        instantaneous) of taxonomy kind ``span_kind`` (default mapped from
        the legacy kind)."""
        self.now = max(self.now, float(at))
        self._commit(float(at), kind, worker, t0, span_kind, nbytes)

    def annotate(self, kind: str, t0: float, t1: float, *, worker: int = -1,
                 lane: Optional[str] = None, nbytes: int = 0,
                 name: str = "") -> None:
        """Add an annotation-only span (never enters the tuple trace):
        barrier waits, queue/contention waits, overlapped comm."""
        if t1 <= t0:
            return
        self.spans.append(Span(kind, lane or worker_lane(worker),
                               float(t0), float(t1), name=name,
                               nbytes=int(nbytes), worker=worker))

    def _commit(self, at: float, kind: str, worker: int,
                t0: Optional[float], span_kind: Optional[str],
                nbytes: int) -> None:
        sk = span_kind or _SPAN_KIND.get(kind, "comm.exposed")
        self.spans.append(Span(sk, worker_lane(worker),
                               float(at if t0 is None else t0), float(at),
                               name=kind, nbytes=int(nbytes), worker=worker,
                               src_kind=kind))

    @property
    def pending(self) -> int:
        return len(self._heap)


@dataclass
class WorkerClocks:
    """One simulated clock per worker."""

    t: List[float]

    @classmethod
    def start(cls, m: int, at: float = 0.0) -> "WorkerClocks":
        return cls([float(at)] * m)

    @property
    def m(self) -> int:
        return len(self.t)

    def advance(self, worker: int, dt: float) -> float:
        self.t[worker] += dt
        return self.t[worker]

    def barrier(self, active: Optional[Sequence[int]] = None) -> float:
        """Synchronize: every (active) clock jumps to the latest of them —
        returns that time.  Inactive workers (elastic leavers) keep their
        clocks and do not gate the barrier."""
        if active is None:
            sync = max(self.t)
            self.t = [sync] * self.m
            return sync
        sync = max(self.t[i] for i in active)
        for i in active:
            self.t[i] = sync
        return sync

    def set_all(self, at: float, active: Optional[Sequence[int]] = None) -> None:
        if active is None:
            self.t = [float(at)] * self.m
        else:
            for i in active:
                self.t[i] = float(at)


def barrier_all_reduce(
    loop: EventLoop,
    clocks: WorkerClocks,
    compute_dts: Sequence[float],
    comm_time: float,
    *,
    kind: str = "all_reduce",
    active: Optional[Sequence[int]] = None,
    nbytes: int = 0,
) -> float:
    """The bulk-synchronous collective: per-worker compute, barrier, exchange.

    Commits a ``compute`` completion per (active) worker in (time, worker)
    order — identical to draining the loop's heap, whose FIFO tiebreak is
    the worker-ascending scheduling order, but additionally valid when a
    fast worker's round starts before an already-committed event of a
    slower worker (the first barriered FO sync after a run of unbarriered
    async rounds) — barriers, then charges ``comm_time`` once: the model
    every method in ``repro.core`` follows.  Returns the completion time,
    with every participating clock advanced to it.  ``comm_time == 0``
    records a plain ``barrier`` event (an iteration with no exchange, e.g.
    PA-SGD between averaging rounds).  ``active`` (elastic membership)
    restricts participation: left workers neither compute nor gate the
    barrier.

    Span timeline: each worker's compute interval, a ``barrier`` wait
    annotation for every worker that finished before the slowest, and one
    ``comm.exposed`` span ``[sync, done]`` carrying ``nbytes`` (the round's
    ledger bytes).
    """
    assert len(compute_dts) == clocks.m
    workers = range(clocks.m) if active is None else active
    dones = sorted((clocks.t[i] + compute_dts[i], i) for i in workers)
    for t_done, i in dones:
        loop.record(t_done, "compute", i, t0=t_done - compute_dts[i])
        clocks.t[i] = t_done
    sync = clocks.barrier(active)
    for t_done, i in dones:
        loop.annotate("barrier", t_done, sync, worker=i, name="barrier.wait")
    if comm_time > 0:
        done = sync + comm_time
        loop.record(done, kind, t0=sync, nbytes=nbytes)
    else:
        done = sync
        loop.record(done, "barrier", nbytes=nbytes)
    clocks.set_all(done, active)
    return done


@dataclass
class SharedLink:
    """A shared-bandwidth resource serving one transfer at a time.

    ``acquire(at, duration)`` queues a transfer that becomes ready at
    ``at``: it starts at ``max(at, free_at)`` — waiting behind whatever is
    already on the wire — and returns its completion time, advancing the
    link's busy horizon.  Callers MUST acquire in deterministic
    (ready_time, worker) order; the FIFO discipline then yields the
    deterministic contention sharing the README §repro.sim contract pins:
    two transfers of duration ``g`` both ready at ``T`` complete at
    ``T + g`` and ``T + 2g`` (aggregate throughput = fair bandwidth share,
    with a deterministic completion order instead of fractional-rate
    bookkeeping).  Zero-duration requests pass through untouched.
    """

    free_at: float = 0.0

    def acquire(self, at: float, duration: float) -> float:
        if duration <= 0.0:
            return float(at)
        start = max(float(at), self.free_at)
        self.free_at = start + float(duration)
        return self.free_at


@dataclass
class LinkContention:
    """Per-link contention state for unbarriered exchanges: one
    ``SharedLink`` per pod plus one shared inter-pod link (the multi-pod
    bottleneck).  A worker's exchange routes through its pod's link for the
    intra-pod component, then the inter-pod link for the inter component
    (zero on single-pod clusters) — concurrent transfers on the same link
    serialize instead of being priced independently.

    Barriered collectives do NOT route through these links: the
    ``CollectiveModel`` already prices the whole membership's joint
    algorithm, and the barrier guarantees nothing else is in flight.
    """

    m: int
    pods: int = 1
    pod_links: Optional[List[SharedLink]] = None
    inter: SharedLink = field(default_factory=SharedLink)

    def __post_init__(self):
        assert self.pods >= 1 and self.m >= 1
        if self.pod_links is None:
            self.pod_links = [SharedLink() for _ in range(self.pods)]

    def pod_of(self, worker: int) -> int:
        wpp = max(1, self.m // self.pods)
        return min(worker // wpp, self.pods - 1)

    def transfer(self, worker: int, at: float, intra_s: float,
                 inter_s: float = 0.0) -> float:
        t1 = self.pod_links[self.pod_of(worker)].acquire(at, intra_s)
        return self.inter.acquire(t1, inter_s)

    def clone(self) -> "LinkContention":
        return LinkContention(
            self.m, self.pods,
            [SharedLink(l.free_at) for l in self.pod_links],
            SharedLink(self.inter.free_at))

    def adopt(self, other: "LinkContention") -> None:
        for mine, theirs in zip(self.pod_links, other.pod_links):
            mine.free_at = theirs.free_at
        self.inter.free_at = other.inter.free_at


class AsyncEntry(NamedTuple):
    """One worker's planned unbarriered round: compute ``[start, t_done]``,
    then an exchange of duration ``comm_s`` ending at ``end`` (any gap
    between ``t_done`` and ``end - comm_s`` is shared-link queueing)."""

    t_done: float
    worker: int
    start: float
    end: float
    comm_s: float


def plan_async_round(
    clocks: WorkerClocks,
    compute_dts: Sequence[float],
    gate: float,
    workers: Sequence[int],
    comm_for,
    contention: Optional[LinkContention] = None,
):
    """Pure planning pass for one unbarriered round.

    ``comm_for(i) -> (intra_s, inter_s)`` gives worker ``i``'s exchange
    duration split (overlap-aware: the runner passes the EXPOSED time).
    Returns ``(entries, trial)`` where ``entries`` is a list of
    ``AsyncEntry`` in deterministic (time, worker) order and ``trial`` is
    the advanced CLONE of ``contention`` (or None) — nothing global is
    mutated, so the runner can price a tentative commit (failure
    preemption) and only ``adopt`` the link state if the round really
    lands.
    """
    trial = contention.clone() if contention is not None else None
    entries = []
    for t_done, i in sorted((max(clocks.t[i], gate) + compute_dts[i], i)
                            for i in workers):
        intra_s, inter_s = comm_for(i)
        if trial is not None:
            end = trial.transfer(i, t_done, intra_s, inter_s)
        else:
            end = t_done + intra_s + inter_s
        entries.append(AsyncEntry(t_done, i, t_done - compute_dts[i], end,
                                  intra_s + inter_s))
    return entries, trial


def commit_async_round(
    loop: EventLoop,
    clocks: WorkerClocks,
    entries,
    *,
    kind: str = "async_exchange",
    nbytes: int = 0,
) -> float:
    """Commit a planned unbarriered round: per-worker ``compute`` events in
    the plan's (time, worker) order, clocks advanced to each worker's
    exchange end, one ``kind`` event at the round's commit time (the latest
    participating clock).

    Span timeline: each worker's compute interval, a ``queue.contention``
    annotation covering any shared-link wait between compute completion and
    exchange start, a ``comm.exposed`` annotation for the exchange itself,
    and the round-commit event as a zero-length ``comm.exposed`` span
    carrying ``nbytes`` (the round's ledger bytes, booked once)."""
    for e in entries:
        loop.record(e.t_done, "compute", e.worker, t0=e.start)
        comm_t0 = e.end - e.comm_s
        if comm_t0 - e.t_done > 1e-12:  # real link wait, not float residue
            loop.annotate("queue.contention", e.t_done, comm_t0,
                          worker=e.worker, name="link.wait")
        loop.annotate("comm.exposed", comm_t0, e.end, worker=e.worker,
                      name="exchange")
        clocks.t[e.worker] = e.end
    done = max(e.end for e in entries)
    loop.record(done, kind, nbytes=nbytes)
    return done


def async_all_reduce(
    loop: EventLoop,
    clocks: WorkerClocks,
    compute_dts: Sequence[float],
    comm_time: float,
    gate: float,
    *,
    kind: str = "async_exchange",
    active: Optional[Sequence[int]] = None,
    contention: Optional[LinkContention] = None,
) -> float:
    """Bounded-staleness round: compute + exchange WITHOUT a barrier.

    Each (active) worker starts at ``max(own clock, gate)`` — ``gate`` is
    the commit time of the round ``max_staleness + 1`` back, which is how
    the runner enforces that no worker runs more than ``max_staleness``
    rounds ahead of the slowest — computes for its own ``dt``, then pays
    ``comm_time`` for its own unbarriered exchange.  Clocks diverge; fast
    workers pull ahead.  With ``contention``, the per-worker exchanges
    additionally serialize through the shared links in the same
    deterministic (time, worker) order (``plan_async_round``).

    Completions are committed with ``loop.record`` in (time, worker) order
    *within the round*; across rounds a fast worker's completion may carry
    an earlier timestamp than an already-committed slow-worker event — the
    trace is a deterministic function of the inputs either way, which is
    all the determinism contract pins.  Returns the round's commit time
    (the latest participating clock, recorded as one ``kind`` event).
    """
    assert len(compute_dts) == clocks.m
    workers = list(range(clocks.m)) if active is None else list(active)
    comm = comm_time if comm_time > 0 else 0.0
    entries, trial = plan_async_round(clocks, compute_dts, gate, workers,
                                      lambda i: (comm, 0.0), contention)
    if contention is not None and trial is not None:
        contention.adopt(trial)
    return commit_async_round(loop, clocks, entries, kind=kind)
