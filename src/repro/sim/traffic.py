"""Open-loop traffic model: serving on the same time-to-X axis as training.

Seeded Poisson arrivals with prompt/output-length mixes are replayed against
the REAL continuous-batching scheduler (``repro.serving``): the scheduler
generates actual tokens, and this module prices each scheduler step with the
training-side ``ComputeModel`` — a prefill costs the bucket's tokens of
forward FLOPs, a decode step costs one forward token per live slot — so
"train with HO-SGD, serve the result" reads off one frontier in the same
cost vocabulary (tokens/sec and p50/p99 TTFT/latency vs simulated seconds).

Open loop: arrivals never wait for service — a saturated pool grows the
queue and the latency tail, it doesn't thin the arrival process.

Determinism contract (same as ``repro.sim``): same ``TrafficSpec`` seed ⇒
bit-identical event trace, per-request latency table and summary.  All
randomness (inter-arrival gaps, length draws, prompt tokens) comes from one
``np.random.default_rng(seed)``; simulated time is pure arithmetic over it.

``replay_seed_sync`` prices the seed engine's synchronous batch path (left-
padded rectangle, no early exit, next batch waits for the previous) on the
same trace — the baseline ``benchmarks/serve_bench.py`` compares against.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.sim.costs import ComputeModel, config_fwd_flops

#: named prompt/output-length mixes for the CLI / benchmarks
MIXES: Dict[str, Dict[str, Tuple[int, ...]]] = {
    "short": dict(prompt_lens=(4, 8, 12), prompt_weights=(1, 1, 1),
                  out_lens=(8, 16), out_weights=(1, 1)),
    "mixed": dict(prompt_lens=(4, 16, 48), prompt_weights=(2, 1, 1),
                  out_lens=(4, 16, 32), out_weights=(1, 2, 1)),
    "long": dict(prompt_lens=(32, 96), prompt_weights=(1, 1),
                 out_lens=(32, 64), out_weights=(1, 1)),
}


@dataclass(frozen=True)
class TrafficSpec:
    """Open-loop workload: Poisson(rate) arrivals of mixed-shape requests."""

    rate: float                             # mean arrivals per simulated sec
    n_requests: int
    prompt_lens: Tuple[int, ...] = (4, 16, 48)
    prompt_weights: Optional[Tuple[float, ...]] = None
    out_lens: Tuple[int, ...] = (4, 16, 32)
    out_weights: Optional[Tuple[float, ...]] = None
    vocab: int = 512                        # prompt tokens ~ U[0, vocab)
    seed: int = 0

    def required_max_seq(self) -> int:
        return max(self.prompt_lens) + max(self.out_lens)

    @staticmethod
    def from_mix(rate: float, n_requests: int, mix: str = "mixed",
                 seed: int = 0, vocab: int = 512) -> "TrafficSpec":
        return TrafficSpec(rate=rate, n_requests=n_requests, seed=seed,
                           vocab=vocab, **MIXES[mix])


@dataclass(frozen=True)
class Arrival:
    t: float
    prompt: Tuple[int, ...]
    max_new: int


@dataclass
class TrafficResult:
    events: List[Tuple]                     # deterministic event trace
    rows: List[Dict]                        # per-request latency table
    summary: Dict[str, float]
    wall_s: float = 0.0                     # host wall clock, NOT deterministic


def _norm(weights, n) -> np.ndarray:
    w = np.ones(n, float) if weights is None else np.asarray(weights, float)
    return w / w.sum()


def poisson_trace(spec: TrafficSpec) -> List[Arrival]:
    """Seeded arrival trace: exponential gaps, weighted length mixes."""
    assert spec.rate > 0 and spec.n_requests >= 1
    rng = np.random.default_rng(spec.seed)
    gaps = rng.exponential(1.0 / spec.rate, spec.n_requests)
    times = np.cumsum(gaps)
    p_lens = rng.choice(np.asarray(spec.prompt_lens), spec.n_requests,
                        p=_norm(spec.prompt_weights, len(spec.prompt_lens)))
    o_lens = rng.choice(np.asarray(spec.out_lens), spec.n_requests,
                        p=_norm(spec.out_weights, len(spec.out_lens)))
    return [
        Arrival(float(times[i]),
                tuple(int(t) for t in rng.integers(0, spec.vocab, int(p_lens[i]))),
                int(o_lens[i]))
        for i in range(spec.n_requests)
    ]


def serve_compute_model(cfg, flops_per_sec: float = 1e12) -> ComputeModel:
    """Per-TOKEN forward-FLOP unit: ``time(fevals=k)`` prices k token
    forwards, so prefill = bucket tokens and decode = live slots."""
    return ComputeModel(fwd_flops=config_fwd_flops(cfg, 1, 1),
                        flops_per_sec=flops_per_sec)


@dataclass(frozen=True)
class StepOverheads:
    """Per-step fixed serving overheads (ROADMAP serving follow-up (4)).

    ``dispatch_s`` is charged once per priced program launch — each prefill
    bucket and each decode step (host-side dispatch, argument staging);
    ``sample_s`` once per decode step (sampling + detokenize host work).
    Both are fixed per STEP, not per token, which is what makes the slots
    axis price batching amortization: a decode step over ``live`` slots
    spreads the same overhead across ``live`` tokens, so tokens/sec now
    rises with slot count instead of being FLOP-flat.  Both replay paths
    (continuous and the seed synchronous batch) charge the identical
    discipline, so the comparison stays fair; the zero default keeps every
    pre-overhead pin bit-identical.
    """

    dispatch_s: float = 0.0
    sample_s: float = 0.0

    def __post_init__(self):
        assert self.dispatch_s >= 0.0 and self.sample_s >= 0.0

    @property
    def decode_s(self) -> float:
        return self.dispatch_s + self.sample_s


#: the zero-overhead default (pure-FLOP pricing, the pre-overhead contract)
NO_OVERHEADS = StepOverheads()


def _percentile(vals: Sequence[float], q: float) -> float:
    """Deterministic nearest-rank percentile (no interpolation)."""
    s = sorted(vals)
    if not s:
        return 0.0
    k = max(1, int(np.ceil(q * len(s)))) - 1
    return float(s[k])


def _ttft_percentiles(rows: Sequence[Dict]) -> Dict[str, float]:
    """The shared latency-percentile block, with TTFT decomposed into its
    queueing (arrival → admission) and service (admission → first token)
    components — per-row ``ttft == queue_s + service_s`` exactly."""
    out = {}
    for key, col in (("ttft", "ttft"), ("latency", "latency"),
                     ("queue", "queue_s"), ("service", "service_s")):
        vals = [r[col] for r in rows]
        out[f"p50_{key}_s"] = _percentile(vals, 0.50)
        out[f"p99_{key}_s"] = _percentile(vals, 0.99)
    return out


def replay(engine, spec: TrafficSpec, compute: ComputeModel,
           overheads: StepOverheads = NO_OVERHEADS,
           tracer=None) -> TrafficResult:
    """Drive a fresh ``serving.Engine`` open-loop under ``spec``, pricing
    every scheduler step with ``compute`` plus the per-step fixed
    ``overheads`` (dispatch per launch, sampling per decode step).  Returns
    the event trace, the per-request latency table and summary statistics.

    ``tracer`` (a sim-clock ``repro.obs.Tracer``) additionally records each
    request's lifecycle on its admission slot's lane — ``queue.contention``
    (arrival → admission), ``prefill`` (admission → first token), ``decode``
    (first token → retire) — plus a live-slot counter per decode step; the
    spans are stamped from the SAME clock the pricing advances, so tracing
    never perturbs the deterministic events/rows/summary.
    """
    import time as _time

    assert engine.sc.max_seq >= spec.required_max_seq(), \
        "engine max_seq too small for the traffic mix"
    assert not engine.has_work, "replay needs a fresh engine"
    if tracer is not None:
        assert tracer.clock == "sim", "traffic replay stamps simulated time"
    t_wall = _time.perf_counter()
    arrivals = poisson_trace(spec)
    n = len(arrivals)
    events: List[Tuple] = []
    arrival_t: Dict[int, float] = {}
    prompt_len: Dict[int, int] = {}
    budget: Dict[int, int] = {}
    ttft: Dict[int, float] = {}
    queue_s: Dict[int, float] = {}
    done: Dict[int, float] = {}
    lane: Dict[int, str] = {}
    first_tok: Dict[int, float] = {}
    total_tokens = 0
    clock = 0.0
    i = 0
    while len(done) < n:
        while i < n and arrivals[i].t <= clock:
            a = arrivals[i]
            rid = engine.submit(list(a.prompt), a.max_new)
            arrival_t[rid] = a.t
            prompt_len[rid] = len(a.prompt)
            budget[rid] = a.max_new
            events.append(("arrive", rid, a.t))
            i += 1
        if not engine.has_work:
            clock = arrivals[i].t    # idle: jump to the next arrival
            continue
        rep = engine.step()
        prefill_clock: Dict[int, float] = {}
        for rid, L, bucket, slot in rep.admitted:
            admit = clock
            clock += compute.time(fevals=bucket, gevals=0) + overheads.dispatch_s
            prefill_clock[rid] = clock
            ttft[rid] = clock - arrival_t[rid]
            queue_s[rid] = admit - arrival_t[rid]
            events.append(("prefill", rid, L, bucket, clock))
            if tracer is not None:
                from repro.obs.trace import slot_lane
                lane[rid] = slot_lane(slot)
                first_tok[rid] = clock
                tracer.add("queue.contention", lane[rid], arrival_t[rid],
                           admit, name=f"queue/r{rid}")
                tracer.add("prefill", lane[rid], admit, clock,
                           name=f"prefill/{bucket}")
        if rep.live:
            clock += (compute.time(fevals=rep.live, gevals=0)
                      + overheads.dispatch_s + overheads.sample_s)
            events.append(("decode", rep.live, len(rep.emitted), clock))
            if tracer is not None:
                tracer.counter(clock, "slots", "live_slots", rep.live)
        total_tokens += len(rep.emitted)
        for rid, phase in rep.finished:
            t_done = prefill_clock[rid] if phase == "prefill" else clock
            done[rid] = t_done
            events.append(("done", rid, phase, t_done))
            if tracer is not None and phase == "decode":
                tracer.add("decode", lane[rid], first_tok[rid], t_done,
                           name=f"decode/r{rid}")
    rows = [
        dict(rid=rid, arrival=arrival_t[rid], prompt_len=prompt_len[rid],
             max_new=budget[rid], ttft=ttft[rid], queue_s=queue_s[rid],
             service_s=ttft[rid] - queue_s[rid],
             latency=done[rid] - arrival_t[rid], finish=done[rid])
        for rid in sorted(done)
    ]
    makespan = clock
    summary = dict(
        n_requests=float(n),
        total_tokens=float(total_tokens),
        makespan_s=makespan,
        tok_per_sec=total_tokens / makespan if makespan > 0 else 0.0,
        **_ttft_percentiles(rows),
    )
    return TrafficResult(events, rows, summary,
                         wall_s=_time.perf_counter() - t_wall)


def replay_seed_sync(spec: TrafficSpec, compute: ComputeModel,
                     batch: int,
                     overheads: StepOverheads = NO_OVERHEADS) -> TrafficResult:
    """Price the SEED synchronous batch path on the same arrival trace.

    Semantics of the seed ``Engine.generate`` under an offline driver that
    groups arrivals FIFO into fixed batches: a batch starts once the
    previous finished AND its last request arrived; prefill pays the
    left-padded ``B × Lmax`` rectangle; decode pays ``B`` tokens per step
    for ``max(max_new) - 1`` steps (no EOS, no early retirement — every
    request is carried to the rectangle's end, only its own ``max_new``
    tokens count as useful).  Per-step ``overheads`` follow the same
    discipline as ``replay``: dispatch per launch, sampling per decode
    step.  Pricing-only: token values cannot change the seed path's cost,
    so nothing is generated.
    """
    assert batch >= 1
    arrivals = poisson_trace(spec)
    events: List[Tuple] = []
    rows: List[Dict] = []
    clock = 0.0
    total_tokens = 0
    for g0 in range(0, len(arrivals), batch):
        group = arrivals[g0:g0 + batch]
        B = len(group)
        ready = max(a.t for a in group)
        start = max(clock, ready)
        l_max = max(len(a.prompt) for a in group)
        steps = max(a.max_new for a in group)
        first = start + compute.time(fevals=B * l_max, gevals=0) \
            + overheads.dispatch_s
        finish = first + (steps - 1) * (compute.time(fevals=B, gevals=0)
                                        + overheads.dispatch_s
                                        + overheads.sample_s)
        events.append(("batch", g0 // batch, B, l_max, steps, start, finish))
        for j, a in enumerate(group):
            rid = g0 + j
            rows.append(dict(rid=rid, arrival=a.t, prompt_len=len(a.prompt),
                             max_new=a.max_new, ttft=first - a.t,
                             queue_s=start - a.t, service_s=first - start,
                             latency=finish - a.t, finish=finish))
            total_tokens += a.max_new
        clock = finish
    summary = dict(
        n_requests=float(len(arrivals)),
        total_tokens=float(total_tokens),
        makespan_s=clock,
        tok_per_sec=total_tokens / clock if clock > 0 else 0.0,
        **_ttft_percentiles(rows),
    )
    return TrafficResult(events, rows, summary)
