"""Replay the real training steps through the discrete-event cluster model.

``simulate`` drives an actual method one iteration at a time; the event loop
prices each iteration on the simulated cluster — per-worker compute from the
FLOP model, a barriered alpha–beta collective for the exchange — and emits a
loss-vs-simulated-seconds history.  That collapses the paper's three
incommensurable axes (bytes, function evals, loss-vs-iteration) onto one:
time to target loss.

Replay modes (``simulate(..., replay=...)``):

* ``"per_worker"`` (default) — the method's ``repro.core.rounds`` program is
  replayed round by round, PER WORKER: under elastic membership only the
  live workers' shards, coefficients and gradients enter the collective
  (the trajectory genuinely changes, and the live-W collective prices
  exactly the payload each active worker sent), and under bounded
  staleness each async ZO worker evaluates its coefficient at the params
  version it actually had when it started the round.  On a synchronous
  full-membership round every worker's view is current, so the runner
  executes the round through the SAME monolithic jitted program the
  distributed runtime lowers — the per-worker replay is bit-identical to
  the monolithic one there (``tests/test_replay_fidelity.py``).
* ``"monolithic"`` — the PR-4 behavior: the all-m-workers step programs run
  unconditionally and membership/staleness change only pricing and event
  structure, never the computed trajectory (kept for the pricing-only
  contract and as the regression reference).

Byte counts are never re-derived analytically:

* HO-SGD (fixed and adaptive tau), sync-SGD and ZO-SGD replay the
  *distributed* step programs from ``core.distributed`` wrapped in a
  ``CommLedger`` — each synchronous iteration is priced at exactly the
  bytes its compiled program booked (including any FO compressor's wire
  estimate, per-worker or legacy mode).  Per-worker rounds carry their
  bytes out of the round IR's single wire model
  (``rounds.wire_nbytes`` — also what the executor books when wrapped).
* PA-SGD / RI-SGD exchange the model tree itself every tau iterations
  (gossip-PA its ring neighbors' trees); the byte count is measured from
  the live parameter tree.
* QSGD's wire size comes from ``repro.dist.compress.qsgd(s).nbytes`` —
  per-worker mode books ``nbytes`` × active workers (the real protocol),
  ``legacy`` the historical post-reduction single payload.
* Federated methods (``fed_ho_sgd`` / ``fed_avg`` / ``fed_dropout_avg``,
  on a ``ClusterSpec`` with ``n_clients``/``cohort_k``) replay every round
  over a freshly sampled K-of-N client cohort with availability churn; the
  collective is priced and booked at the LIVE cohort (per-client payload ×
  |cohort|, never × N) straight from the executor's wire model.

Failure injection does REAL checkpoint round-trips through
``repro.checkpoint``: the cluster periodically saves ``{params, state}``,
and a failure restores from the latest step — so a lossy method-state
round-trip would corrupt the simulated run, not just a counter.
"""
from __future__ import annotations

import bisect
import math
import shutil
import tempfile
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.checkpoint import restore as ckpt_restore
from repro.checkpoint import save as ckpt_save
from repro.core import rounds as R
from repro.core.baselines import (
    make_gossip_pa_sgd, make_pa_sgd, make_qsgd, make_ri_sgd,
)
from repro.core import federated as F
from repro.core.distributed import make_fo_step, make_zo_step
from repro.core.ho_sgd import HOSGDConfig
from repro.dist import CommLedger
from repro.dist import compress as compress_mod
from repro.dist.collectives import _tree_nbytes
from repro.launch.mesh import make_test_mesh
from repro.opt.optimizers import Optimizer, const_schedule, sgd
from repro.sim.cluster import ClusterSpec
from repro.sim.costs import (ComputeModel, StepCost, exposed_comm_time,
                             tree_fwd_flops)
from repro.sim.events import (
    EventLoop,
    LinkContention,
    WorkerClocks,
    barrier_all_reduce,
    commit_async_round,
    plan_async_round,
)

REPLAY_MODES = ("per_worker", "monolithic")


@dataclass
class SimMethod:
    """A replayable method: real step functions + per-iteration price tags.

    ``step`` has the ``Method.step`` signature (the monolithic all-m-workers
    program); ``costs_for(t, order)`` prices the iteration that just ran
    (the runner calls it after ``step``, so ledger-backed byte counts are
    always taken from a traced program).  ``program`` is the method's
    ``repro.core.rounds.RoundProgram`` — the per-worker replay handle; the
    runner builds a ``RoundExecutor`` from it and consults
    ``program.round_for`` for the coming round's order (pricing needs it
    before the math runs).
    """

    name: str
    init: Callable[[Any], Any]
    step: Callable[..., tuple]
    costs_for: Callable[[int, int], StepCost]
    ledger: Optional[CommLedger] = None
    program: Optional[R.RoundProgram] = None
    executor: Optional[R.RoundExecutor] = None

    def __post_init__(self):
        if self.program is not None and self.executor is None:
            self.executor = R.RoundExecutor(self.program)

    def order_for(self, t: int, state) -> int:
        assert self.program is not None
        return self.program.round_for(t, state).round.order

    def overlap_for(self, t: int, state) -> int:
        """Bucket count of the coming round's overlap spec (1 = strict
        compute-then-communicate — every round without an explicit
        ``rounds.Overlap`` prices exactly as before)."""
        if self.program is None:
            return 1
        ov = getattr(self.program.round_for(t, state).round, "overlap", None)
        return ov.buckets if ov is not None else 1


@dataclass
class SimResult:
    """Loss-vs-simulated-seconds history plus the committed event trace."""

    name: str
    steps: List[int] = field(default_factory=list)      # iteration index
    times: List[float] = field(default_factory=list)    # completion (sim s)
    losses: List[float] = field(default_factory=list)   # training-batch loss
    orders: List[int] = field(default_factory=list)
    comm_bytes: List[int] = field(default_factory=list)  # wire bytes/worker
    active_counts: List[int] = field(default_factory=list)  # live W/iteration
    feval_cum: List[float] = field(default_factory=list)
    evals: List[Tuple[float, float, float]] = field(default_factory=list)
    #: committed (time, kind, worker) entries — the determinism contract
    trace: List[tuple] = field(default_factory=list)
    #: the committed ``repro.obs`` spans the tuple trace is derived from —
    #: feed to ``repro.obs.export.write_trace`` / ``report.attribution``
    spans: List[Any] = field(default_factory=list)
    compute_s: float = 0.0      # critical-path compute seconds
    comm_s: float = 0.0
    feval_s: float = 0.0        # compute seconds spent on function evals
    geval_s: float = 0.0        # compute seconds spent on gradient evals
    bytes_total: int = 0        # per-worker wire bytes, summed over iters
    failures: int = 0
    rejoins: int = 0            # elastic membership re-entries
    params: Any = None
    state: Any = None           # final method state (opt + counters)

    @property
    def sim_seconds(self) -> float:
        return self.times[-1] if self.times else 0.0

    def _series(self) -> List[Tuple[float, float, float]]:
        """(sim_time, value, feval_seconds) — eval series when present
        (stable held-out loss), else the noisy training-loss series."""
        if self.evals:
            return self.evals
        return list(zip(self.times, self.losses, self.feval_cum))

    def time_to_loss(self, target: float) -> float:
        for t_sim, v, _ in self._series():
            if v <= target:
                return t_sim
        return math.inf

    def feval_seconds_to_loss(self, target: float) -> float:
        for _, v, fs in self._series():
            if v <= target:
                return fs
        return math.inf

    def summary(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "iters": len(self.steps),
            "sim_seconds": self.sim_seconds,
            "compute_s": self.compute_s,
            "comm_s": self.comm_s,
            "feval_s": self.feval_s,
            "geval_s": self.geval_s,
            "bytes_per_worker": self.bytes_total,
            "failures": self.failures,
            "rejoins": self.rejoins,
            "final_loss": self.losses[-1] if self.losses else math.nan,
        }


def compute_model_for(params_like: Any, cluster: ClusterSpec,
                      per_worker_batch: int, *,
                      fwd_flops: Optional[float] = None) -> ComputeModel:
    """Default FLOP pricing for a parameter tree on this cluster."""
    return ComputeModel(
        fwd_flops=(fwd_flops if fwd_flops is not None
                   else tree_fwd_flops(params_like, per_worker_batch)),
        flops_per_sec=cluster.flops_per_sec,
    )


def simulate(
    sm: SimMethod,
    params: Any,
    batches,                      # iterable of (m*B, ...) global batches
    cluster: ClusterSpec,
    n_iters: int,
    *,
    compute: ComputeModel,
    eval_fn: Optional[Callable[[Any], float]] = None,
    eval_every: int = 0,
    target_loss: Optional[float] = None,
    ckpt_dir: Optional[str] = None,
    key=None,
    max_failures: int = 100,
    replay: str = "per_worker",
) -> SimResult:
    """Run ``sm`` for up to ``n_iters`` committed iterations of simulated
    time (early-stop at ``target_loss``); returns the priced history.

    Determinism: same ``cluster`` (seed included), same method, data and
    ``replay`` mode ⇒ bit-identical ``SimResult.trace``.  All randomness
    flows from ``cluster.rng()`` in a fixed draw order (slowdowns are drawn
    for all ``m`` workers even when some have elastically left, so
    membership changes never shift later draws); simulated time never reads
    a wall clock.

    Async (``cluster.max_staleness > 0``): ZO iterations run unbarriered —
    each worker starts a round as soon as it finished its previous one AND
    the round ``max_staleness + 1`` back has committed cluster-wide, and
    (per-worker replay) evaluates its coefficient at the params version it
    actually had at that start time; FO sync rounds always barrier at the
    fully-committed params (HO-SGD's consistency point).  Elastic
    (``cluster.elastic``): a failure removes the victim from the membership
    with NO rollback; the survivors' round re-runs at the live ``W`` — its
    collective priced at the payload those workers actually sent — and the
    victim rejoins after a seeded downtime through a real
    ``repro.checkpoint`` round-trip of the current ``{params, state}``.
    Under ``replay="monolithic"`` membership and staleness change pricing
    and event structure only (the PR-4 contract).
    """
    assert replay in REPLAY_MODES, \
        f"unknown replay mode {replay!r}; have {REPLAY_MODES}"
    use_pw = replay == "per_worker"
    if use_pw:
        assert sm.executor is not None, \
            f"per-worker replay needs a RoundProgram on method {sm.name!r}"
    # federated partial participation: every round runs over a freshly
    # sampled K-of-N client cohort (cluster.sampling's seeded schedule —
    # the same draw the round executor makes), priced at the LIVE cohort
    fed = sm.program.client_sampling if sm.program is not None else None
    if fed is not None:
        assert use_pw, ("federated client-sampling replay needs "
                        "replay='per_worker' (the cohort IS the membership)")
        assert cluster.m == fed.cohort_k, (
            f"cluster m={cluster.m} must equal the program's "
            f"cohort_k={fed.cohort_k}")
        assert cluster.max_staleness == 0 and not cluster.elastic \
            and cluster.fail_rate == 0, \
            "federated rounds are server-synchronous: availability churn " \
            "is the only membership dynamic"
    loop = EventLoop()
    clocks = WorkerClocks.start(cluster.m)
    rng = cluster.rng()
    state = sm.init(params)
    res = SimResult(name=sm.name)
    it = iter(batches)
    if eval_fn is not None and eval_every <= 0:
        eval_every = 1

    tmp = None
    use_ckpt = cluster.ckpt_every > 0
    last_ckpt = 0       # the step THIS run last saved (a caller-supplied
    if use_ckpt or cluster.elastic:   # ckpt_dir may hold stale checkpoints
        if ckpt_dir is None:          # from other runs
            tmp = tempfile.mkdtemp(prefix="repro_sim_ckpt_")
            ckpt_dir = tmp
        if use_ckpt:
            ckpt_save(ckpt_dir, 0, {"params": params, "state": state})
    next_fail = cluster.draw_failure_gap(rng)

    stale = cluster.max_staleness
    # shared-link state for unbarriered exchanges (per-pod + inter-pod);
    # barriered collectives never route through it, so synchronous specs
    # are untouched by the flag
    pods = cluster.topology.pods if cluster.topology is not None else 1
    contention = (LinkContention(cluster.m, pods)
                  if cluster.contention and stale > 0 else None)
    active = list(range(cluster.m))   # live membership, ascending order
    rejoin_at: Dict[int, float] = {}  # left worker -> rejoin time
    pending = None   # monolithic replay: the in-flight (batch consumed)
                     # step, kept across elastic repricing passes so a
                     # failure never skips a batch — there membership
                     # changes the PRICE of iteration t, never its math
    cur_batch = None  # per-worker replay: the iteration's batch, kept
                      # across repricing passes (membership changes which
                      # SHARDS of it enter the round, never skips it)
    # params history for bounded-staleness views: round index -> params
    # after that round committed (-1 = the initial params).  commit_times
    # is the CURRENT LINEAGE's commit times, index-aligned with round t —
    # unlike res.times it is truncated on a bulk-synchronous rollback, so
    # view selection never counts commits of an abandoned lineage
    track_views = use_pw and stale > 0
    phist: Dict[int, Any] = {-1: params} if track_views else {}
    commit_times: List[float] = []

    t = 0
    try:
        while t < n_iters:
            # elastic rejoins whose downtime has elapsed re-enter here (in
            # worker order), through a REAL checkpoint round-trip of the
            # cluster's current state
            if rejoin_at:
                for w in sorted(rejoin_at):
                    back = rejoin_at[w]
                    if back > loop.now:
                        continue
                    del rejoin_at[w]
                    ckpt_save(ckpt_dir, t, {"params": params, "state": state})
                    restored, _ = ckpt_restore(
                        ckpt_dir, {"params": params, "state": state}, step=t)
                    params, state = restored["params"], restored["state"]
                    resume = back + cluster.restart_time
                    loop.record(back, "rejoin", w)
                    loop.record(resume, "restore", w, t0=back)
                    clocks.t[w] = resume
                    active = sorted(active + [w])
                    res.rejoins += 1

            if use_pw:
                if cur_batch is None:
                    cur_batch = next(it)
                order = sm.order_for(t, state)
                sc = sm.costs_for(t, order)
            elif pending is None:
                batch = next(it)
                new_params, new_state, metrics = sm.step(t, params, state,
                                                         batch, key)
                order = int(metrics["order"])
                sc = sm.costs_for(t, order)
                pending = (new_params, new_state, metrics, order, sc)
            else:
                new_params, new_state, metrics, order, sc = pending
            # price the iteration (host floats only; fixed draw order —
            # slowdowns always drawn for all m workers)
            slow = cluster.draw_slowdowns(rng)
            base_dt = compute.time(sc.fevals, sc.gevals)
            dts = [base_dt * float(s) for s in slow]
            is_async = stale > 0 and order == 0
            if is_async:
                idx = len(res.times) - 1 - stale
                gate = res.times[idx] if idx >= 0 else 0.0
            else:
                gate = 0.0

            cohort = None
            if use_pw and fed is not None:
                # federated replay: draw this round's live cohort (the same
                # seeded schedule the executor would draw) and run ONLY the
                # sampled clients; bytes are what the executor's wire model
                # booked for the live cohort, never re-derived
                cohort = list(fed.cohort_for(t))
                new_params, new_state, metrics = sm.executor.run(
                    t, params, state, cur_batch, workers=cohort, key=key)
                comm_bytes = int(metrics["comm_bytes"])
                assert int(metrics["order"]) == order, (sm.name, t, order)
            elif use_pw:
                # per-worker replay: the live membership's rounds run with
                # the params each worker actually has.  On a synchronous
                # full-membership round every view is current, so the round
                # executes through the SAME monolithic jitted program the
                # runtime lowers (bit-identical replay); divergent views or
                # a shrunken membership force the per-worker path.
                views, lagged = None, False
                if is_async:
                    views = {}
                    for w in active:
                        start_w = max(clocks.t[w], gate)
                        v = bisect.bisect_right(commit_times, start_w)
                        if v < t:               # stale view: after round v-1
                            views[w] = phist[v - 1]
                            lagged = True
                    if not lagged:
                        views = None
                if len(active) == cluster.m and not lagged:
                    new_params, new_state, metrics = sm.step(
                        t, params, state, cur_batch, key)
                    sc = sm.costs_for(t, int(metrics["order"]))
                    comm_bytes = sc.comm_bytes
                else:
                    new_params, new_state, metrics = sm.executor.run(
                        t, params, state, cur_batch, workers=active,
                        views=views, key=key)
                    comm_bytes = int(metrics["comm_bytes"])
                assert int(metrics["order"]) == order, (sm.name, t, order)
            else:
                comm_bytes = sc.comm_bytes

            # overlap-aware pricing: with the round's payload split into B
            # buckets, only the exposed tail of the collective lands on the
            # critical path (costs.exposed_comm_time; B=1 exposes it all —
            # the historical price, bit-identical).  Bytes are whatever the
            # replayed programs booked, never rescaled by overlap.
            cm = cluster.collective_model
            # the round's live membership: the sampled cohort occupies the
            # first len(cohort) worker slots (slot i runs cohort[i]; slowdown
            # draws stay per-SLOT so churn never shifts later draws)
            live = active if cohort is None else list(range(len(cohort)))
            w_live = len(live)
            buckets = sm.overlap_for(t, state)
            dt_crit = max(dts[i] for i in live)
            exposed_crit = exposed_comm_time(cm, comm_bytes, w_live,
                                             buckets, dt_crit)
            entries = trial = None
            if is_async:
                # per-worker exchanges: each worker's exposed time uses its
                # OWN compute (a straggler hides more), split into intra-/
                # inter-pod components so contention routes each through
                # the right shared link
                intra_f, inter_f = cm.time_components(comm_bytes, w_live)
                total_f = intra_f + inter_f

                def comm_for(i):
                    e = exposed_comm_time(cm, comm_bytes, w_live, buckets,
                                          dts[i])
                    if total_f <= 0.0:
                        return 0.0, 0.0
                    return e * intra_f / total_f, e * inter_f / total_f

                entries, trial = plan_async_round(
                    clocks, dts, gate, active, comm_for, contention)
                done_tent = max(e.end for e in entries)
            else:
                done_tent = max(clocks.t[i] + dts[i]
                                for i in live) + exposed_crit

            if next_fail < done_tent:
                if cluster.elastic:
                    # the victim leaves; survivors continue with NO rollback.
                    # Monolithic replay keeps the in-flight step result and
                    # reprices it at the shrunken membership on the next
                    # pass; per-worker replay re-RUNS the round with the
                    # survivors' shards only (the batch itself is never
                    # skipped).  A failure with one live worker left has
                    # nothing to remove and is not counted — the failures
                    # counter matches leave events.
                    victim = active[int(rng.integers(len(active)))]
                    down = cluster.draw_downtime(rng)
                    if len(active) > 1:
                        loop.record(next_fail, "leave", victim)
                        active = [i for i in active if i != victim]
                        rejoin_at[victim] = next_fail + down
                        # causality: the survivors only learn of the failure
                        # at next_fail (they were waiting on the victim's
                        # barrier slot / exchange), so the re-run round
                        # cannot start — let alone commit — before it
                        for i in active:
                            clocks.t[i] = max(clocks.t[i], next_fail)
                        res.failures += 1
                        if res.failures >= max_failures:
                            break
                    next_fail = next_fail + cluster.draw_failure_gap(rng)
                    continue
                # bulk-synchronous mode: the failure lands inside this
                # iteration, its work is lost; the cluster restores the last
                # checkpoint (a real repro.checkpoint round-trip) and pays
                # the restart charge
                res.failures += 1
                pending = None      # rollback: t changes, the step is stale
                cur_batch = None
                victim = int(rng.integers(cluster.m))
                loop.record(next_fail, "fail", victim)
                restored, rstep = ckpt_restore(
                    ckpt_dir, {"params": params, "state": state},
                    step=last_ckpt)
                params, state = restored["params"], restored["state"]
                t = int(rstep)
                if track_views:
                    # the rounds past the checkpoint belong to an abandoned
                    # lineage: drop their commits from the view index and
                    # resolve any staleness window to the restored params
                    del commit_times[t:]
                    phist = {k: params for k in range(t - 1 - stale, t)}
                resume = next_fail + cluster.restart_time
                loop.record(resume, "restore", t0=next_fail)
                clocks.set_all(resume)
                if res.failures >= max_failures:
                    break
                next_fail = resume + cluster.draw_failure_gap(rng)
                continue

            # commit: drain per-worker compute through the event loop, then
            # the exchange — barriered (FO sync / bulk-synchronous mode,
            # charged its exposed tail) or staleness-gated (async rounds:
            # the planned unbarriered exchanges, adopting the shared-link
            # state only now that the round really lands)
            if is_async:
                if contention is not None and trial is not None:
                    contention.adopt(trial)
                round_start = min(e.start for e in entries)
                done = commit_async_round(loop, clocks, entries,
                                          nbytes=comm_bytes)
                # per-worker overlapped share: full collective minus the
                # exposed tail this worker's own compute could not hide
                total_f = sum(cm.time_components(comm_bytes, w_live))
                for e in entries:
                    hid = total_f - e.comm_s
                    if hid > 1e-15:
                        loop.annotate("comm.overlapped",
                                      max(e.start, e.t_done - hid), e.t_done,
                                      worker=e.worker, name="overlap")
            else:
                round_start = min(clocks.t[i] for i in live)
                done = barrier_all_reduce(loop, clocks, dts, exposed_crit,
                                          active=live, nbytes=comm_bytes)
                if cohort is not None:
                    # server round: every slot resumes at the commit — the
                    # next cohort is dispatched from the committed params
                    clocks.set_all(done)
                # the bucketed collective's hidden share rides behind the
                # round's compute, ending at the barrier point
                hid = cm.all_reduce_time(comm_bytes, w_live) - exposed_crit
                if hid > 1e-15:
                    sync = done - exposed_crit
                    loop.annotate("comm.overlapped",
                                  max(round_start, sync - hid), sync,
                                  name="overlap")
            res.compute_s += dt_crit
            res.comm_s += exposed_crit
            if order == 0:
                res.feval_s += dt_crit
            else:
                res.geval_s += dt_crit
            res.bytes_total += comm_bytes
            params, state = new_params, new_state
            pending = None
            cur_batch = None
            res.steps.append(t)
            res.times.append(done)
            res.losses.append(float(metrics["loss"]))
            res.orders.append(order)
            res.comm_bytes.append(comm_bytes)
            res.active_counts.append(w_live)
            res.feval_cum.append(res.feval_s)
            if track_views:
                phist[t] = params
                for k in [k for k in phist if k < t - stale]:
                    del phist[k]
                commit_times.append(done)
            t += 1

            if use_ckpt and t % cluster.ckpt_every == 0:
                ckpt_save(ckpt_dir, t, {"params": params, "state": state})
                last_ckpt = t
            if eval_fn is not None and t % eval_every == 0:
                v = float(eval_fn(params))
                res.evals.append((done, v, res.feval_s))
                if target_loss is not None and v <= target_loss:
                    break
            elif (eval_fn is None and target_loss is not None
                    and res.losses[-1] <= target_loss):
                break
    finally:
        if tmp is not None:
            shutil.rmtree(tmp, ignore_errors=True)
    res.trace = list(loop.trace)
    res.spans = list(loop.spans)
    res.params = params
    res.state = state
    return res


# --------------------------------------------------------------------------- #
# method factories
# --------------------------------------------------------------------------- #
def _ho_family(
    loss_fn: Callable,
    cluster: ClusterSpec,
    *,
    name: str,
    tau: int,
    lr: float,
    zo_lr: Optional[float],
    mu: float,
    seed: int,
    opt: Optional[Optimizer] = None,
    codec=None,
    tau_schedule: Optional[Callable[[int], int]] = None,
    zo_only: bool = False,
    engine: str = "fused",
    compress_mode: str = "per_worker",
    overlap_buckets: int = 1,
) -> SimMethod:
    """HO-SGD spectrum: the round program (``rounds.ho_sgd_program``) plus
    its monolithic lowering to the real distributed step programs (1x1
    mesh, ``m`` simulated workers in-program — the 0.4.x auto-sharded ZO
    path), wrapped in a ``CommLedger`` so costs_for reads measured bytes.
    ``overlap_buckets > 1`` attaches a ``rounds.Overlap`` spec to both round
    kinds — the sim prices the exposed comm tail, the lowering chunks the
    gradient reduce, bytes stay bit-identical."""
    mesh = make_test_mesh(data=1, model=1)
    ho = HOSGDConfig(tau=tau, mu=mu, m=cluster.m, lr=lr, zo_lr=zo_lr,
                     seed=seed, engine=engine)
    opt = opt or sgd(const_schedule(lr))
    wire = R.Wire(codec, compress_mode, seed=seed)
    overlap = R.Overlap(overlap_buckets) if overlap_buckets > 1 else None
    program = R.ho_sgd_program(loss_fn, ho, opt, name=name, wire=wire,
                               tau_schedule=tau_schedule, zo_only=zo_only,
                               overlap=overlap)
    ledger = CommLedger()
    fo = make_fo_step(loss_fn, mesh, opt, compressor=codec, seed=seed,
                      compress_mode=compress_mode, m=cluster.m,
                      buckets=overlap_buckets)
    zo = make_zo_step(loss_fn, mesh, ho, opt, m=cluster.m)
    fo_j = ledger.wrap("fo", jax.jit(fo))
    zo_j = ledger.wrap("zo", jax.jit(zo))

    # the since-FO counter rides in the sim state so a checkpoint restore
    # also restores the adaptive schedule position
    def init(params):
        return {"opt": opt.init(params), "since_fo": 0}

    def step(t, params, state, batch, key=None):
        # the monolithic lowering of program.round_for's schedule: the FO/ZO
        # decision is the SAME host logic the round program runs
        rstep = program.round_for(t, state)
        is_fo = rstep.round.order == 1
        params, opt_state, loss = (fo_j if is_fo else zo_j)(
            jnp.int32(rstep.t_step), params, state["opt"], batch)
        return params, {"opt": opt_state, **rstep.host_updates}, {
            "loss": loss, "order": 1 if is_fo else 0}

    def costs_for(t, order):
        # the FO iteration is one gradient eval; the ZO iteration is two
        # function evals per worker (eq. 4's forward differences) — the
        # per-order resolution of Method.fevals/gevals.  Bytes come from
        # what the traced program booked.
        if order == 1:
            return StepCost(0.0, 1.0, ledger.bytes_per_step("fo"))
        return StepCost(2.0, 0.0, ledger.bytes_per_step("zo"))

    return SimMethod(name, init, step, costs_for, ledger, program=program)


def _averaging_baseline(
    which: str,
    loss_fn: Callable,
    params_like: Any,
    cluster: ClusterSpec,
    *,
    tau: int,
    lr: float,
    mu_r: float = 0.25,
    qsgd_s: int = 8,
    compress_mode: str = "per_worker",
) -> SimMethod:
    d = sum(int(x.size) for x in jax.tree.leaves(params_like))
    if which == "pa_sgd":
        meth = make_pa_sgd(loss_fn, cluster.m, tau, lr)
    elif which == "pa_gossip":
        meth = make_gossip_pa_sgd(loss_fn, cluster.m, tau, lr)
    elif which == "ri_sgd":
        meth = make_ri_sgd(loss_fn, cluster.m, tau, lr, mu_r=mu_r)
    elif which == "qsgd":
        meth = make_qsgd(loss_fn, cluster.m, qsgd_s, lr,
                         compress_mode=compress_mode)
    else:
        raise ValueError(which)

    # PA/RI move the model tree itself on averaging rounds (gossip-PA its
    # min(2, m-1) ring neighbors' trees) — bytes measured from the live
    # parameter tree (the ledger's own counter), not a formula on d
    model_bytes = _tree_nbytes(params_like)
    sync_bytes = (model_bytes * min(2, cluster.m - 1)
                  if which == "pa_gossip" else model_bytes)
    # QSGD's wire size: the repo's one QSGD wire model (per-leaf headers);
    # per-worker mode receives every active worker's code (the real
    # protocol), legacy the historical single post-reduction payload
    qsgd_bytes = sum(compress_mod.qsgd(qsgd_s).nbytes(int(x.size))
                     for x in jax.tree.leaves(params_like))
    if compress_mode == "per_worker":
        qsgd_bytes *= cluster.m

    def costs_for(t, order):
        fe, ge = meth.fevals(d), meth.gevals(d)
        if which == "qsgd":
            return StepCost(fe, ge, qsgd_bytes)
        synced = (t + 1) % tau == 0
        return StepCost(fe, ge, sync_bytes if synced else 0)

    return SimMethod(which, meth.init, meth.step, costs_for,
                     program=meth.program)


def _federated_family(
    loss_fn: Callable,
    cluster: ClusterSpec,
    *,
    name: str,
    tau: int,
    lr: float,
    zo_lr: Optional[float],
    mu: float,
    seed: int,
    engine: str = "fused",
    codec=None,
    compress_mode: str = "per_worker",
    local_steps: Optional[int] = None,
    fed_dropout: float = 0.25,
) -> SimMethod:
    """The federated frontier's methods, all over the SAME sampled-cohort
    schedule (``cluster.sampling``):

    * ``fed_ho_sgd`` — HO-SGD with sampled-cohort rounds: the cohort's FO
      gradients all-reduce every tau rounds, its ZO coefficients all-gather
      in between (direction streams keyed on client identity survive the
      sampling);
    * ``fed_avg`` — FedAvg-style local-update averaging: each client runs
      ``local_steps`` (default tau) local SGD steps and the server commits
      the dataset-size-weighted ``masked_average`` of the uploaded models;
    * ``fed_dropout_avg`` — FedDropoutAvg: same, but each client zeroes a
      seeded ``fed_dropout`` fraction of its upload and the masked average
      weighs only the coordinates that actually arrived.

    Bytes always come from the round IR's wire model at the LIVE cohort
    (the executor books them; the runner reads ``metrics["comm_bytes"]``),
    so the ``StepCost`` byte slot is intentionally 0 here.
    """
    cs = cluster.sampling
    assert cs is not None, (
        f"{name!r} needs a federated ClusterSpec: set n_clients/cohort_k "
        f"(and m = cohort_k)")
    if name == "fed_ho_sgd":
        ho = HOSGDConfig(tau=tau, mu=mu, m=cluster.m, lr=lr, zo_lr=zo_lr,
                         seed=seed, engine=engine)
        wire = R.Wire(codec, compress_mode, seed=seed)
        program = R.ho_sgd_program(loss_fn, ho, name=name, wire=wire,
                                   client_sampling=cs)

        def costs_for(t, order):
            if order == 1:
                return StepCost(0.0, 1.0, 0)
            return StepCost(2.0, 0.0, 0)
    elif name in ("fed_avg", "fed_dropout_avg"):
        H = local_steps if local_steps is not None else max(1, tau)
        drop = fed_dropout if name == "fed_dropout_avg" else 0.0
        wire = R.Wire(codec, "per_worker", seed=seed)
        program = F.fed_avg_program(loss_fn, cs, lr=lr, local_steps=H,
                                    dropout=drop, seed=seed, wire=wire,
                                    name=name)

        def costs_for(t, order):
            return StepCost(0.0, float(H), 0)
    else:
        raise ValueError(name)
    meth = R.to_method(program)
    return SimMethod(name, meth.init, meth.step, costs_for, program=program)


def make_sim_methods(
    loss_fn: Callable,
    params_like: Any,
    cluster: ClusterSpec,
    *,
    tau: int = 8,
    lr: float = 0.05,
    zo_lr: Optional[float] = None,
    mu: float = 1e-3,
    seed: int = 0,
    codec=None,
    tau_schedule: Optional[Callable[[int], int]] = None,
    mu_r: float = 0.25,
    qsgd_s: int = 8,
    engine: str = "fused",
    compress_mode: str = "per_worker",
    which: Optional[List[str]] = None,
    overlap_buckets: int = 1,
    local_steps: Optional[int] = None,
    fed_dropout: float = 0.25,
) -> Dict[str, SimMethod]:
    """Build the paper's method zoo as replayable ``SimMethod``s.

    ``zo_lr`` defaults to the paper's ``lr * 30 / d`` scaling.  ``codec``
    (a ``repro.dist.Compressor``) compresses the HO/sync FO exchange and is
    priced at its booked wire bytes — ``compress_mode`` picks the faithful
    per-worker encode (``nbytes`` × live workers) or the legacy
    post-reduction simulation.  ``tau_schedule`` drives ``ho_sgd_adaptive``
    (default: linear ramp 2 -> tau over 10*tau iters).  ``overlap_buckets``
    buckets the HO-family collectives (time only, never bytes); the
    averaging baselines keep the strict compute-then-communicate price.

    The ``fed_*`` methods (``fed_ho_sgd``/``fed_avg``/``fed_dropout_avg``)
    need a federated ``cluster`` (``n_clients``/``cohort_k`` set);
    ``local_steps`` (default tau) and ``fed_dropout`` parameterize the
    FedAvg-family local phase — see ``_federated_family``.
    """
    d = sum(int(x.size) for x in jax.tree.leaves(params_like))
    zo_lr = zo_lr if zo_lr is not None else lr * 30.0 / d
    horizon = max(1, 10 * tau)
    sched = tau_schedule or (
        lambda t: int(round(2 + (tau - 2) * min(t, horizon) / horizon)))
    kw = dict(lr=lr, mu=mu, seed=seed, engine=engine,
              compress_mode=compress_mode, overlap_buckets=overlap_buckets)
    fkw = dict(lr=lr, mu=mu, seed=seed, engine=engine,
               compress_mode=compress_mode)
    avg_kw = dict(tau=tau, lr=lr, compress_mode=compress_mode)
    builders: Dict[str, Callable[[], SimMethod]] = {
        "ho_sgd": lambda: _ho_family(
            loss_fn, cluster, name="ho_sgd", tau=tau, zo_lr=zo_lr,
            codec=codec, **kw),
        "ho_sgd_adaptive": lambda: _ho_family(
            loss_fn, cluster, name="ho_sgd_adaptive", tau=tau, zo_lr=zo_lr,
            codec=codec, tau_schedule=sched, **kw),
        "sync_sgd": lambda: _ho_family(
            loss_fn, cluster, name="sync_sgd", tau=1, zo_lr=None,
            codec=codec, **kw),
        "zo_sgd": lambda: _ho_family(
            loss_fn, cluster, name="zo_sgd", tau=max(2, tau), zo_lr=zo_lr,
            zo_only=True, **kw),
        "pa_sgd": lambda: _averaging_baseline(
            "pa_sgd", loss_fn, params_like, cluster, **avg_kw),
        "pa_gossip": lambda: _averaging_baseline(
            "pa_gossip", loss_fn, params_like, cluster, **avg_kw),
        "ri_sgd": lambda: _averaging_baseline(
            "ri_sgd", loss_fn, params_like, cluster, mu_r=mu_r, **avg_kw),
        "qsgd": lambda: _averaging_baseline(
            "qsgd", loss_fn, params_like, cluster, qsgd_s=qsgd_s, **avg_kw),
        "fed_ho_sgd": lambda: _federated_family(
            loss_fn, cluster, name="fed_ho_sgd", tau=tau, zo_lr=zo_lr,
            codec=codec, **fkw),
        "fed_avg": lambda: _federated_family(
            loss_fn, cluster, name="fed_avg", tau=tau, zo_lr=zo_lr,
            codec=codec, local_steps=local_steps, **fkw),
        "fed_dropout_avg": lambda: _federated_family(
            loss_fn, cluster, name="fed_dropout_avg", tau=tau, zo_lr=zo_lr,
            codec=codec, local_steps=local_steps, fed_dropout=fed_dropout,
            **fkw),
    }
    names = which or list(builders)
    unknown = [n for n in names if n not in builders]
    if unknown:
        raise ValueError(f"unknown sim methods {unknown}; have "
                         f"{sorted(builders)}")
    return {n: builders[n]() for n in names}
