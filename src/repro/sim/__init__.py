"""repro.sim — discrete-event cluster simulator for the paper's tradeoff.

Turns the three incommensurable cost axes (CommLedger bytes, function
evals, loss-vs-iteration) into one: time to target loss on a configurable
simulated cluster.  The scenario substrate for stragglers, heterogeneity,
elastic clusters and failures — extend ``ClusterSpec``/``simulate`` here
the way ``repro.dist`` owns sharding and ``DirectionEngine`` owns ZO
algebra.

  * ``events``  — deterministic event loop, per-worker clocks, the
    barriered all-reduce primitive and its bounded-staleness async twin,
    plus the shared-link contention resources (``SharedLink`` /
    ``LinkContention``) that serialize concurrent unbarriered transfers
    in deterministic (time, worker) order.
  * ``costs``   — pluggable hardware cost models (FLOP-based compute,
    alpha–beta links, ``CollectiveModel`` pricing flat/ring/tree/gossip
    and hierarchical multi-pod all-reduces, overlap-aware exposed-comm
    pricing via ``exposed_comm_time``); byte counts always come from
    the ``CommLedger`` / the round IR's wire model (``rounds.wire_nbytes``
    over ``dist.compress`` estimates), never re-derived.
  * ``cluster`` — ``ClusterSpec``: heterogeneous speeds, seeded straggler
    distributions, Poisson failures charged a real checkpoint-restore,
    ``Topology`` (pods × workers-per-pod), ``max_staleness`` async and
    ``elastic`` leave/rejoin membership.
  * ``runner``  — replays the real round programs from ``core.rounds`` /
    ``core.baselines`` (per worker by default: elastic membership and
    bounded staleness change the trajectory, not just the price;
    ``replay="monolithic"`` keeps the pricing-only PR-4 behavior) and
    emits loss-vs-simulated-seconds traces.
  * ``traffic`` — open-loop serving workloads: seeded Poisson arrivals
    with prompt/output-length mixes replayed against the REAL
    ``repro.serving`` scheduler, each step priced by ``ComputeModel``
    (prefill = bucket tokens, decode = live slots), emitting tokens/sec
    and p50/p99 TTFT/latency under the same determinism contract.
"""
from repro.sim.cluster import (  # noqa: F401
    ClusterSpec,
    Topology,
    bandwidth_constrained,
)
from repro.sim.costs import (  # noqa: F401
    COLLECTIVE_KINDS,
    CollectiveModel,
    ComputeModel,
    LinkModel,
    StepCost,
    config_fwd_flops,
    exposed_comm_time,
    flat_all_reduce_time,
    gossip_exchange_time,
    overlapped_step_time,
    ring_all_reduce_time,
    tree_all_reduce_time,
    tree_fwd_flops,
)
from repro.sim.events import (  # noqa: F401
    EventLoop,
    LinkContention,
    SharedLink,
    WorkerClocks,
    async_all_reduce,
    barrier_all_reduce,
)
from repro.sim.runner import (  # noqa: F401
    SimMethod,
    SimResult,
    compute_model_for,
    make_sim_methods,
    simulate,
)
from repro.sim.traffic import (  # noqa: F401
    MIXES,
    StepOverheads,
    TrafficResult,
    TrafficSpec,
    poisson_trace,
    replay,
    replay_seed_sync,
    serve_compute_model,
)
