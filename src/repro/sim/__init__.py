"""repro.sim — discrete-event cluster simulator for the paper's tradeoff.

Turns the three incommensurable cost axes (CommLedger bytes, function
evals, loss-vs-iteration) into one: time to target loss on a configurable
simulated cluster.  The scenario substrate for stragglers, heterogeneity,
elastic clusters and failures — extend ``ClusterSpec``/``simulate`` here
the way ``repro.dist`` owns sharding and ``DirectionEngine`` owns ZO
algebra.

  * ``events``  — deterministic event loop, per-worker clocks, the
    barriered all-reduce primitive.
  * ``costs``   — pluggable hardware cost models (FLOP-based compute,
    alpha–beta links); byte counts always come from the ``CommLedger`` /
    ``dist.compress`` wire estimates, never re-derived.
  * ``cluster`` — ``ClusterSpec``: heterogeneous speeds, seeded straggler
    distributions, Poisson failures charged a real checkpoint-restore.
  * ``runner``  — replays the real step functions from ``core`` /
    ``core.baselines`` and emits loss-vs-simulated-seconds traces.
"""
from repro.sim.cluster import ClusterSpec, bandwidth_constrained  # noqa: F401
from repro.sim.costs import (  # noqa: F401
    ComputeModel,
    LinkModel,
    StepCost,
    config_fwd_flops,
    tree_fwd_flops,
)
from repro.sim.events import EventLoop, WorkerClocks, barrier_all_reduce  # noqa: F401
from repro.sim.runner import (  # noqa: F401
    SimMethod,
    SimResult,
    compute_model_for,
    make_sim_methods,
    simulate,
)
