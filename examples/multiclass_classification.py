"""Paper §5.2: distributed training of the 1.69M-param 2-layer MLP on the
four multiclass datasets, comparing all methods.

    PYTHONPATH=src python examples/multiclass_classification.py \
        --datasets acoustic seismic --iters 150
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse

from repro.apps.classification import run_comparison


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--datasets", nargs="*",
                    default=["sensorless", "acoustic", "covtype", "seismic"])
    ap.add_argument("--iters", type=int, default=150)
    ap.add_argument("--hidden", type=int, default=1300)
    ap.add_argument("--tau", type=int, default=8)
    ap.add_argument("--methods", nargs="*", default=None)
    args = ap.parse_args()

    for ds in args.datasets:
        print(f"\n=== {ds} (m=4, B=64, tau={args.tau}) ===")
        res = run_comparison(ds, n_iters=args.iters, hidden=args.hidden,
                             tau=args.tau, methods=args.methods)
        print(f"{'method':14s} {'final loss':>11s} {'test acc':>9s} "
              f"{'scalars/worker':>15s} {'fevals':>8s} {'gevals':>8s} {'wall s':>7s}")
        for name, h in res.items():
            mt = h["meter"]
            print(f"{name:14s} {h['final_loss']:11.4f} {h['final_acc']:9.3f} "
                  f"{mt['scalars_sent_per_worker']:15.1f} "
                  f"{mt['fevals_per_worker']:8.1f} {mt['gevals_per_worker']:8.1f} "
                  f"{h['wall_s']:7.1f}")


if __name__ == "__main__":
    main()
