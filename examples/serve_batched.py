"""Batched serving example: prefill a batch of prompts and decode with the
KV-cache engine (gemma2 family reduced config).

    PYTHONPATH=src python examples/serve_batched.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import serve


def main():
    serve.main(["--arch", "gemma2-2b", "--reduce", "smoke", "--batch", "4",
                "--prompt-len", "24", "--max-new", "12"])


if __name__ == "__main__":
    main()
