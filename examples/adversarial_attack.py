"""Paper §5.1: generate universal adversarial examples from a trained DNN
(d = 900, m = 5 workers, B = 5, step size 30/d — the paper's exact setup),
comparing HO-SGD against syncSGD / RI-SGD / ZO-SGD / ZO-SVRG-Ave.

    PYTHONPATH=src python examples/adversarial_attack.py [--iters 300]
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse

from benchmarks.fig1_attack import run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=300)
    ap.add_argument("--tau", type=int, default=8)
    args = ap.parse_args()
    results = run(n_iters=args.iters, tau=args.tau, verbose=True)

    print("\n=== attack-loss trajectory (every 50 iters) ===")
    header = "iter  " + "".join(f"{n:>13s}" for n in results)
    print(header)
    n_it = len(next(iter(results.values()))["loss_curve"])
    for t in range(0, n_it, 50):
        row = f"{t:5d} " + "".join(
            f"{r['loss_curve'][t]:13.4f}" for r in results.values())
        print(row)
    print("\n=== Table 2 analogue: l2 distortion ===")
    for name, r in results.items():
        print(f"{name:12s} l2={r['l2_all']:.3f} success={r['success_rate']:.2f}")


if __name__ == "__main__":
    main()
