"""End-to-end driver: train a ~100M-param model for a few hundred steps with
HO-SGD on the local device mesh (deliverable b's end-to-end example).

    PYTHONPATH=src python examples/train_100m.py --steps 200
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse

from repro.launch import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--tau", type=int, default=8)
    args = ap.parse_args()
    train.main([
        "--arch", args.arch, "--reduce", "100m", "--steps", str(args.steps),
        "--tau", str(args.tau), "--batch", "8", "--seq", "256",
        "--ckpt", "artifacts/ckpt_100m", "--log", "artifacts/train_100m.csv",
    ])


if __name__ == "__main__":
    main()
