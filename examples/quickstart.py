"""Quickstart: train a small LM with HO-SGD on whatever devices exist.

    PYTHONPATH=src python examples/quickstart.py

Shows the whole public API surface in ~40 lines: config -> model -> data ->
distributed HO-SGD steps -> checkpoint.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs import get_config
from repro.core.distributed import make_distributed_ho_sgd
from repro.core.ho_sgd import HOSGDConfig
from repro.data import shard_batches, token_batches
from repro.launch.mesh import make_test_mesh
from repro.models import transformer as T
from repro.opt.optimizers import sgd, const_schedule


def main():
    cfg = get_config("qwen3-14b").reduced()          # same family, smoke size
    mesh = make_test_mesh(data=1, model=1)           # single CPU device here
    params = T.init_model(jax.random.key(0), cfg)
    d = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {cfg.name}  params={d:,}")

    ho = HOSGDConfig(tau=4, mu=1e-3, lr=5e-2, zo_lr=5e-2 * 20 / d)
    opt = sgd(const_schedule(ho.lr))
    loss_fn = lambda p, b: T.loss_fn(cfg, p, b)
    fo, zo = make_distributed_ho_sgd(loss_fn, mesh, ho, opt, model_cfg=cfg,
                                     params_like=params)

    with compat.set_mesh(mesh):
        fo_j, zo_j = jax.jit(fo), jax.jit(zo)
        opt_state = opt.init(params)
        data = shard_batches(token_batches(cfg.vocab_size, 8, 64), mesh)
        for t, batch in zip(range(24), data):
            step = fo_j if t % ho.tau == 0 else zo_j
            params, opt_state, loss = step(jnp.int32(t), params, opt_state, batch)
            kind = "FO" if t % ho.tau == 0 else "ZO"
            print(f"step {t:3d} [{kind}] loss={float(loss):.4f}")
    print("done")


if __name__ == "__main__":
    main()
