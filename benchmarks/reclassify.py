"""Re-parse saved .hlo.txt.gz artifacts with the current collective
classifier and refresh the ``collectives_raw`` axis fields in the JSONs
(no re-lowering needed)."""
from __future__ import annotations

import glob
import gzip
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from repro.launch import hlo  # noqa: E402


def main(art="artifacts/dryrun"):
    n = 0
    for jf in sorted(glob.glob(os.path.join(art, "*.json"))):
        gz = jf[:-5] + ".hlo.txt.gz"
        if not os.path.exists(gz):
            continue
        with open(jf) as f:
            rec = json.load(f)
        if "collectives_raw" not in rec:
            continue
        ms = 16
        with gzip.open(gz, "rt") as zf:
            text = zf.read()
        rec["collectives_raw"] = hlo.collective_bytes(text, ms)
        # keep extrapolated totals; refresh the axis fields from raw ratios
        with open(jf, "w") as f:
            json.dump(rec, f, indent=1)
        n += 1
    print(f"reclassified {n} artifacts")


if __name__ == "__main__":
    main(*sys.argv[1:])
