"""Measured-vs-analytic communication curves (paper Table 1, in bytes).

Drives the real distributed FO/ZO steps through the CommLedger across the
tau spectrum and the compressor zoo, printing CSV rows:

    comm/tau=<t>[,codec],measured_bytes_per_iter,analytic_bytes_per_iter,ratio

The measured column comes from the ledger (the bytes each compiled step
actually books); the analytic column is 4*(d + (tau-1)*m)/tau — Table 1's
(tau-1+d)/tau load in the ledger's bytes-received convention.  The two
agreeing is the point: the paper's headline tradeoff, observed rather than
assumed.  Runs on any device count (m degenerates gracefully).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.core.distributed import make_distributed_ho_sgd
from repro.core.ho_sgd import HOSGDConfig
from repro.dist import CommLedger, get_compressor
from repro.launch.mesh import make_test_mesh
from repro.opt.optimizers import const_schedule, sgd


def quad_loss(params, batch):
    return 0.5 * jnp.mean(jnp.sum((params["x"] - batch["t"]) ** 2, -1))


def measure(d: int, tau: int, iters: int, codec=None):
    mesh = make_test_mesh(data=1, model=1)
    ho = HOSGDConfig(tau=tau, mu=1e-3, m=1, lr=0.05, zo_lr=0.05 / d)
    opt = sgd(const_schedule(ho.lr))
    fo, zo = make_distributed_ho_sgd(quad_loss, mesh, ho, opt,
                                     compressor=codec)
    ledger = CommLedger()
    fo_j, zo_j = ledger.wrap("fo", jax.jit(fo)), ledger.wrap("zo", jax.jit(zo))
    params = {"x": jnp.zeros((d,), jnp.float32)}
    state = opt.init(params)
    batch = {"t": jnp.ones((4, d), jnp.float32)}
    for t in range(iters):
        step = fo_j if t % tau == 0 else zo_j
        params, state, _ = step(jnp.int32(t), params, state, batch)
    return ledger


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--d", type=int, default=4096)
    ap.add_argument("--iters", type=int, default=16)
    args = ap.parse_args(argv)
    d, m = args.d, 1

    print("name,us_per_call,measured_bytes_per_iter,analytic_bytes_per_iter,"
          "ratio_vs_sync")
    sync_bytes = 4.0 * d
    for tau in (1, 2, 4, 8, 16):
        # whole periods only, or the FO step's amortization is truncated
        iters = tau * max(1, args.iters // tau)
        ledger = measure(d, tau, iters)
        measured = ledger.total_bytes() / iters
        analytic = 4.0 * (d + (tau - 1) * m) / tau
        print(f"comm/tau={tau},0,{measured:.1f},{analytic:.1f},"
              f"{measured / sync_bytes:.4f}")
    for name in ("qsgd", "signsgd", "topk"):
        codec = get_compressor(name)
        tau = 8
        iters = tau * max(1, args.iters // tau)
        ledger = measure(d, tau, iters, codec=codec)
        measured = ledger.total_bytes() / iters
        # analytic: the codec's wire model replaces 4*d on the FO step
        analytic = (codec.nbytes(d) + (tau - 1) * 4.0 * m) / tau
        print(f"comm/tau={tau}+{name},0,{measured:.1f},{analytic:.1f},"
              f"{measured / sync_bytes:.4f}")


if __name__ == "__main__":
    main()
