"""Paper Fig. 2: training-loss / test-accuracy comparison on the four
multi-class datasets (SENSORLESS, ACOUSTIC, COVTYPE, SEISMIC) with the
1.69M-param 2-layer MLP, m=4 workers, B=64, tau=8."""
from __future__ import annotations

import argparse

from repro.apps.classification import run_comparison

DATASETS = ("sensorless", "acoustic", "covtype", "seismic")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--datasets", nargs="*", default=list(DATASETS))
    ap.add_argument("--iters", type=int, default=150)
    ap.add_argument("--hidden", type=int, default=1300)
    ap.add_argument("--methods", nargs="*", default=None)
    args = ap.parse_args(argv)

    print("name,us_per_call,final_loss,final_test_acc,scalars_per_worker")
    for ds in args.datasets:
        res = run_comparison(ds, n_iters=args.iters, hidden=args.hidden,
                             methods=args.methods)
        for name, h in res.items():
            us = 1e6 * h["wall_s"] / args.iters
            print(f"fig2/{ds}/{name},{us:.1f},{h['final_loss']:.4f},"
                  f"{h['final_acc']:.3f},"
                  f"{h['meter']['scalars_sent_per_worker']:.1f}")


if __name__ == "__main__":
    main()
