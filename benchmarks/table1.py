"""Paper Table 1: convergence order / communication load / normalized
computational load per method — analytic columns from core.theory plus
*measured* per-iteration communication/evaluation counters from the cost
model wired into every Method."""
from __future__ import annotations

from repro.core.theory import Problem, table1_row, theorem1_bound


def main():
    # the paper's §5.2 regime: d > 1.69e6, m = 4, B = 64
    p = Problem(d=1_690_000, m=4, B=64, N=100_000)
    tau = 8
    print("name,us_per_call,conv_order,comm_scalars_per_iter,comp_normalized")
    for meth in ("ho_sgd", "ri_sgd", "sync_sgd", "zo_sgd", "zo_svrg_ave", "qsgd"):
        row = table1_row(meth, p, tau=tau)
        print(f"table1/{meth},0.0,{row['conv']:.3e},{row['comm']:.3e},"
              f"{row['comp']:.3e}")
    # Theorem 1 bound decomposition at the paper's parameter choices
    b = theorem1_bound(p, tau)
    print("# theorem1 terms:", {k: f"{v:.2e}" for k, v in b.items()})


if __name__ == "__main__":
    main()
