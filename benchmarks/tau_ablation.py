"""Ablation: the tau spectrum (§3.3) — HO-SGD interpolates syncSGD (tau=1)
and ZO-SGD (tau=inf).  Measures final loss/accuracy and the modeled
communication per worker across tau on one classification task, plus the
beyond-paper adaptive-tau variant."""
from __future__ import annotations

import argparse

import jax

from repro.core import HOSGDConfig, make_ho_sgd, run_method
from repro.core.ho_sgd import make_adaptive_ho_sgd
from repro.data.synthetic import batches, make_classification
from repro.models.mlp import init_mlp_classifier, mlp_accuracy, mlp_loss


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=120)
    ap.add_argument("--hidden", type=int, default=256)
    ap.add_argument("--dataset", default="acoustic")
    args = ap.parse_args(argv)

    m, B, lr = 4, 64, 0.1
    ds = make_classification(args.dataset, n_train=8192, n_test=2048)
    params0 = init_mlp_classifier(jax.random.key(0), ds.n_features,
                                  ds.n_classes, hidden=args.hidden)
    d = sum(x.size for x in jax.tree.leaves(params0))
    zo_lr = lr * 30.0 / d
    test = {"x": ds.x_test, "y": ds.y_test}

    print("name,us_per_call,final_loss,test_acc,comm_scalars_per_iter")
    taus = [1, 2, 4, 8, 16, 64, 1 << 30]
    for tau in taus:
        meth = make_ho_sgd(mlp_loss, HOSGDConfig(
            tau=tau, mu=1e-3, m=m, lr=lr, zo_lr=zo_lr))
        hist = run_method(meth, params0, batches(ds, m * B, seed=1), args.iters)
        acc = float(mlp_accuracy(hist["params"], test))
        name = "inf" if tau > 1e6 else str(tau)
        import numpy as np
        print(f"tau_ablation/tau={name},0,{np.mean(hist['loss'][-10:]):.4f},"
              f"{acc:.3f},{meth.comm_scalars(d):.1f}")
    # beyond-paper: adaptive tau (grow the ZO stretch over time)
    meth = make_adaptive_ho_sgd(
        mlp_loss, HOSGDConfig(tau=8, mu=1e-3, m=m, lr=lr, zo_lr=zo_lr),
        tau_schedule=lambda t: 2 + t // 30)
    hist = run_method(meth, params0, batches(ds, m * B, seed=1), args.iters)
    acc = float(mlp_accuracy(hist["params"], test))
    import numpy as np
    n_fo = sum(hist["order"])
    comm = (n_fo * d + (args.iters - n_fo)) / args.iters
    print(f"tau_ablation/adaptive,0,{np.mean(hist['loss'][-10:]):.4f},"
          f"{acc:.3f},{comm:.1f}")


if __name__ == "__main__":
    main()
