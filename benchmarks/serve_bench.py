"""Serving frontier: continuous batching vs the seed synchronous batch path.

Sweeps KV-cache slots x open-loop arrival rate x architecture on a seeded
Poisson workload (``repro.sim.traffic``), pricing every scheduler step with
the training-side ``ComputeModel`` — the serving half of the repo's
time-to-X story.  For every cell the REAL continuous-batching engine
(actual tokens generated) is compared against the priced seed synchronous
batch path on the SAME arrival trace, and the run asserts the acceptance
ordering: continuous batching clears strictly more tokens/sec on the mixed
open-loop workload.  Emits root-level ``BENCH_serve.json``.
"""
from __future__ import annotations

import argparse
import json
import os

import jax

from repro.configs import get_config
from repro.models import transformer as T
from repro.serving import Engine, ServeConfig
from repro.sim.traffic import (
    StepOverheads,
    TrafficSpec,
    replay,
    replay_seed_sync,
    serve_compute_model,
)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_cell(cfg, params, spec: TrafficSpec, slots: int, flops: float,
             overheads: StepOverheads):
    cm = serve_compute_model(cfg, flops_per_sec=flops)
    eng = Engine(cfg, params,
                 ServeConfig(max_seq=spec.required_max_seq(), slots=slots))
    cont = replay(eng, spec, cm, overheads)
    sync = replay_seed_sync(spec, cm, batch=slots, overheads=overheads)
    return cont, sync


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="CI-sized sweep")
    ap.add_argument("--archs", nargs="*", default=None)
    ap.add_argument("--slots", nargs="*", type=int, default=None)
    ap.add_argument("--rates", nargs="*", type=float, default=None)
    ap.add_argument("--mix", default="mixed")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--flops-per-sec", type=float, default=1e9)
    ap.add_argument("--dispatch-us", type=float, default=200.0,
                    help="per-step dispatch overhead (µs): each prefill "
                         "bucket and each decode step pays this once, so "
                         "the slots axis prices batching amortization")
    ap.add_argument("--sample-us", type=float, default=50.0,
                    help="per-decode-step sampling/detokenize overhead (µs)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=os.path.join(ROOT, "BENCH_serve.json"))
    args = ap.parse_args(argv)
    overheads = StepOverheads(dispatch_s=args.dispatch_us * 1e-6,
                              sample_s=args.sample_us * 1e-6)

    archs = args.archs or (["qwen3-14b"] if args.smoke
                           else ["qwen3-14b", "gemma2-2b"])
    slots_axis = args.slots or ([2, 4] if args.smoke else [2, 4, 8])
    rates = args.rates or ([100.0, 400.0] if args.smoke
                           else [50.0, 100.0, 400.0, 1600.0])
    n_req = args.requests or (16 if args.smoke else 48)

    rows = []
    orderings = {}
    print("arch,slots,rate,engine,tok_per_sec,p50_ttft_ms,p99_ttft_ms,"
          "p50_latency_ms,p99_latency_ms,makespan_s")
    for arch in archs:
        cfg = get_config(arch).reduced().with_(remat=False)
        params = T.init_model(jax.random.key(args.seed), cfg)
        for slots in slots_axis:
            for rate in rates:
                spec = TrafficSpec.from_mix(
                    rate=rate, n_requests=n_req, mix=args.mix,
                    seed=args.seed, vocab=cfg.vocab_size)
                cont, sync = run_cell(cfg, params, spec, slots,
                                      args.flops_per_sec, overheads)
                for name, res in (("continuous", cont), ("seed_sync", sync)):
                    s = res.summary
                    rows.append(dict(
                        arch=arch, slots=slots, rate=rate, engine=name,
                        mix=args.mix, **s))
                    print(f"{arch},{slots},{rate},{name},"
                          f"{s['tok_per_sec']:.2f},"
                          f"{s['p50_ttft_s']*1e3:.2f},{s['p99_ttft_s']*1e3:.2f},"
                          f"{s['p50_latency_s']*1e3:.2f},"
                          f"{s['p99_latency_s']*1e3:.2f},{s['makespan_s']:.4f}")
                key = f"continuous_beats_sync[{arch},slots={slots},rate={rate}]"
                ok = (cont.summary["tok_per_sec"] >
                      sync.summary["tok_per_sec"])
                orderings[key] = bool(ok)
                assert ok, f"acceptance ordering violated: {key}"

    payload = dict(
        bench="serve",
        config=dict(smoke=args.smoke, archs=archs, slots=slots_axis,
                    rates=rates, mix=args.mix, requests=n_req,
                    flops_per_sec=args.flops_per_sec,
                    dispatch_us=args.dispatch_us, sample_us=args.sample_us,
                    seed=args.seed, out=args.out),
        orderings=orderings,
        rows=rows,
    )
    with open(args.out, "w") as fh:
        json.dump(payload, fh, indent=1)
    print(f"wrote {args.out} ({len(rows)} rows; "
          f"{sum(orderings.values())}/{len(orderings)} orderings hold)")


if __name__ == "__main__":
    main()
