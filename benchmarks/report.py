"""Render the §Dry-run and §Roofline markdown tables from the artifacts."""
from __future__ import annotations

import argparse
from collections import defaultdict

from benchmarks.roofline import load, roofline_terms
from repro.launch.mesh import HW


def fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--art", default="artifacts/dryrun")
    args = ap.parse_args(argv)
    recs = load(args.art)
    pod = [r for r in recs if r.get("mesh") == "pod"]
    mp = [r for r in recs if r.get("mesh") == "multipod"]

    print("### §Dry-run results (single-pod 16x16; per-device numbers)\n")
    print("| arch | shape | step | fits? temp GiB | args GiB | FLOPs/dev | "
          "bytes/dev | coll B/dev (worker-axis) | lower+compile s |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in pod:
        tag = f"{r['arch']} | {r['shape']} | {r['step']}"
        if not r.get("applicable"):
            print(f"| {tag} | skip: {r['skip_reason']} | | | | | |")
            continue
        if "error" in r:
            print(f"| {tag} | ERROR {r['error'][:40]} | | | | | |")
            continue
        mem = r["memory"]
        temp = mem.get("temp_size_in_bytes", 0)
        fits = "yes" if temp <= 16 * 2**30 else "**no**"
        cw = r.get("roofline", {}).get("worker_bytes", 0)
        print(
            f"| {tag} | {fits} {fmt_bytes(temp)} | "
            f"{fmt_bytes(mem.get('argument_size_in_bytes', 0))} | "
            f"{r['cost']['flops']:.2e} | {r['cost']['bytes']:.2e} | "
            f"{r['collectives']['total']:.2e} ({cw:.2e}) | "
            f"{r['lower_s']}+{r['compile_s']} |"
        )

    if mp:
        n_ok = sum(1 for r in mp if r.get("applicable") and "error" not in r)
        n_skip = sum(1 for r in mp if not r.get("applicable"))
        n_err = sum(1 for r in mp if "error" in r)
        print(f"\n### §Dry-run multi-pod (2x16x16): {n_ok} compiled, "
              f"{n_skip} skipped, {n_err} errors\n")
        for r in mp:
            if "error" in r:
                print(f"* ERROR {r['arch']} x {r['shape']} ({r['step']}): "
                      f"{r['error'][:120]}")

    print("\n### §Roofline (single-pod; seconds per step at v5e peaks)\n")
    print("| arch | shape | step | t_compute | t_memory | t_collective | "
          "dominant | 6ND/HLO | what would move the dominant term |")
    print("|---|---|---|---|---|---|---|---|---|")
    hints = {
        ("memory",): "fuse/kernelize the dominant streaming op (flash attn / "
                     "selective-scan Pallas kernels); bf16 intermediates",
        ("compute",): "reduce remat recompute; MXU-align tiles",
        ("collective",): "overlap collectives with compute; reduce-scatter "
                         "instead of all-reduce; larger per-step compute",
    }
    for r in pod:
        if not r.get("applicable") or "roofline" not in r:
            continue
        rf = r["roofline"]
        print(
            f"| {r['arch']} | {r['shape']} | {r['step']} | "
            f"{rf['t_compute']:.3e} | {rf['t_memory']:.3e} | "
            f"{rf['t_collective']:.3e} | {rf['dominant']} | "
            f"{rf['model_flops_ratio']:.2f} | {hints[(rf['dominant'],)]} |"
        )

    # candidates for the three hillclimb pairs
    print("\n### Hillclimb candidates\n")
    scored = []
    for r in pod:
        if not r.get("applicable") or "roofline" not in r:
            continue
        rf = r["roofline"]
        scored.append((r, rf))
    if scored:
        worst_eff = min(scored, key=lambda x: x[1]["model_flops_ratio"] or 9)
        most_coll = max(scored, key=lambda x: x[1]["t_collective"]
                        / max(x[1]["bound_s"], 1e-30))
        print(f"* worst MODEL_FLOPS/HLO ratio: {worst_eff[0]['arch']} x "
              f"{worst_eff[0]['shape']} ({worst_eff[0]['step']}) = "
              f"{worst_eff[1]['model_flops_ratio']:.2f}")
        print(f"* most collective-bound: {most_coll[0]['arch']} x "
              f"{most_coll[0]['shape']} ({most_coll[0]['step']})")
        zo = [x for x in scored if x[0]["step"] == "zo"]
        if zo:
            big = max(zo, key=lambda x: x[1]["bound_s"])
            print(f"* most paper-representative (ZO step): {big[0]['arch']} x "
                  f"{big[0]['shape']}")


if __name__ == "__main__":
    main()
