"""Time-to-target-loss frontier on the simulated cluster (repro.sim).

Sweeps tau, m, the FO codec (per-worker vs legacy wire accounting — the
compress-mode axis showing the honest QSGD byte cost), straggler severity,
the link topology (flat/ring/tree/gossip all-reduce, 1 vs 2 pods), the
async staleness bound and — under ``--federated`` — K-of-N partial
participation (``federated_axis``: sampled client cohorts with
availability churn); every configuration replays the REAL round programs
through the discrete-event cluster model and reports when (in simulated
seconds) it reaches the target loss.  This is the paper's Table-1 tradeoff collapsed
onto one axis — and the benchmark asserts the qualitative ordering on a
bandwidth-constrained cluster:

  * HO-SGD reaches the target in fewer simulated seconds than sync-SGD
    (the FO exchange amortized over tau) — on the base cluster AND under
    a ring all-reduce AND a 2-pod hierarchical topology, and
  * in fewer function-evaluation-seconds than ZO-only SGD (the FO anchor
    steps do the heavy lifting).

CSV rows: ``sim/<config>,us_per_call,t_to_target,feval_s_to_target,...``
plus a BENCH json dump (``--out``, default ``BENCH_sim_frontier.json`` at
the repo root so the bench harness picks it up) with the full per-config
summaries.
"""
from __future__ import annotations

import argparse
import json
import math
import os

import jax

from repro.data.synthetic import batches, make_classification
from repro.dist import get_compressor
from repro.models.mlp import init_mlp_classifier, mlp_loss
from repro.sim import (
    COLLECTIVE_KINDS,
    ClusterSpec,
    Topology,
    bandwidth_constrained,
    compute_model_for,
    make_sim_methods,
    simulate,
)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

FIELDS = ["t_to_target", "feval_s_to_target", "iters", "sim_seconds",
          "comm_s", "compute_s", "failures", "final_loss"]


def run_one(name, sm, params, ds, cluster, *, iters, batch, target, seed):
    compute = compute_model_for(params, cluster, batch // cluster.m)
    eval_batch = {"x": ds.x_test, "y": ds.y_test}
    eval_fn = jax.jit(lambda p: mlp_loss(p, eval_batch))
    res = simulate(sm, params, batches(ds, batch, seed=seed), cluster, iters,
                   compute=compute, eval_fn=eval_fn, eval_every=1,
                   target_loss=target)
    s = res.summary()
    s["t_to_target"] = res.time_to_loss(target)
    s["feval_s_to_target"] = res.feval_seconds_to_loss(target)
    s["config"] = name
    return s


def fmt(v):
    if isinstance(v, float):
        return "inf" if math.isinf(v) else f"{v:.6g}"
    return str(v)


def trace_report(args, acceptance, results, B, tau, batch, params):
    """Derive the overlap headline from the exported trace ALONE.

    Writes each bucketed cell's spans as a Perfetto JSON
    (``BENCH_sim_frontier_trace_<topo>_<method>.json`` — picked up by the
    same CI artifact glob as the benchmark dumps), then recomputes the
    attribution purely from the file and asserts the PR-7 claim in trace
    vocabulary: HO-SGD's exposed-comm fraction ≤ 0.05 vs sync-SGD's ≥ 0.2,
    on both topologies — with the trace's ``comm.exposed`` seconds
    cross-checked against the ``costs.exposed_comm_time`` closed forms
    (one per iteration, from the ledger bytes and the round's order) to
    within 1e-9.
    """
    from repro.obs import attribution_from_file, format_report, write_trace
    from repro.sim.costs import exposed_comm_time

    for tag in ("ring-1pod", "ring-2pod"):
        for method, kind in (("ho_sgd", "hidden"), ("sync_sgd", "exposed")):
            label = f"{tag}][{method}][B={B}"
            res, cluster = results[label]
            path = os.path.join(
                REPO_ROOT, f"BENCH_sim_frontier_trace_{tag}_{method}.json")
            write_trace(path, res.spans, title=f"overlap:{label}")
            att = attribution_from_file(path)
            for line in format_report(att, title=f"trace[{label}]"):
                print(line)
            frac = att["exposed_comm_fraction"]
            if method == "ho_sgd":
                acceptance[f"trace_ho_comm_hidden[{tag}]"] = frac <= 0.05
            else:
                acceptance[f"trace_sync_comm_exposed[{tag}]"] = frac >= 0.20
            # closed-form cross-check: Σ_t exposed_comm_time(bytes_t, dt_t)
            compute = compute_model_for(params, cluster, batch // cluster.m)
            cm = cluster.collective_model
            closed = 0.0
            for order, nb in zip(res.orders, res.comm_bytes):
                dt = (compute.time(2.0, 0.0) if order == 0
                      else compute.time(0.0, 1.0))
                closed += exposed_comm_time(cm, nb, cluster.m, B, dt)
            traced = att["kind_seconds"]["comm.exposed"]
            acceptance[f"trace_closed_form[{tag}][{method}]"] = \
                abs(traced - closed) <= 1e-9
            print(f"sim/trace_cross_check[{label}],0,{fmt(traced)},"
                  f"{fmt(closed)},{fmt(abs(traced - closed))}")
            print(f"# wrote {path}")


def overlap_axis(args, ds, params):
    """Latency-honest axis: compute/communication overlap + per-link
    contention (the ISSUE-7 acceptance criterion).

    Runs on a DEDICATED cluster point — m=4, 1 GFLOP/s workers, a 50 MB/s
    ring with 1 µs latency (2-pod variant: 100 MB/s inter-pod ring) — chosen
    so the FO gradient collective is a few times one worker's compute:
    bucketed overlap can then hide HO-SGD's comm almost entirely (its tau−1
    ZO rounds move 4·m bytes ≈ free; the lone FO round amortizes over the
    window) while sync-SGD pays an exposed tail EVERY iteration.  Asserts,
    per topology (1-pod ring and 2-pod hierarchical):

      * ho_sgd  overlapped: sim_seconds ≤ 1.05 × compute_s (comm hidden);
      * sync_sgd overlapped: sim_seconds ≥ 1.20 × compute_s (comm exposed);
      * CommLedger bytes bit-identical with overlap on vs off.

    Then the contention sub-axis: the same point run async
    (max_staleness=2, stragglers) with shared-link contention on vs off —
    serializing concurrent exchanges can only delay, never change bytes.
    Writes ``--overlap-out`` (BENCH_sim_frontier_overlap.json).
    """
    B, iters, tau, batch = (args.overlap_buckets, args.overlap_iters, 16, 64)
    base = ClusterSpec(m=4, flops_per_sec=1e9, alpha=1e-6, bandwidth=5e7,
                       collective="ring", seed=args.seed)
    topos = {
        "ring-1pod": None,
        "ring-2pod": Topology(pods=2, inter_alpha=1e-6, inter_bandwidth=1e8),
    }
    rows = []
    results = {}   # label -> (SimResult, cluster) for --trace-report

    def cell(label, cluster, method, buckets):
        sm = make_sim_methods(mlp_loss, params, cluster, tau=tau, lr=args.lr,
                              seed=args.seed, which=[method],
                              overlap_buckets=buckets)[method]
        compute = compute_model_for(params, cluster, batch // cluster.m)
        res = simulate(sm, params, batches(ds, batch, seed=args.seed),
                       cluster, iters, compute=compute)
        results[label] = (res, cluster)
        row = dict(config=label, method=method, buckets=buckets,
                   contention=cluster.contention,
                   staleness=cluster.max_staleness,
                   sim_seconds=res.sim_seconds, compute_s=res.compute_s,
                   comm_s=res.comm_s,
                   exposed_ratio=res.sim_seconds / res.compute_s,
                   bytes_total=res.bytes_total,
                   comm_bytes=list(res.comm_bytes))
        rows.append(row)
        print(f"sim/overlap[{label}],0,{fmt(row['sim_seconds'])},"
              f"{fmt(row['compute_s'])},{fmt(row['comm_s'])},"
              f"{fmt(row['exposed_ratio'])},{row['bytes_total']}")
        return row

    print("name,us_per_call,sim_seconds,compute_s,comm_s,exposed_ratio,"
          "bytes_total")
    acceptance = {}
    for tag, topo in topos.items():
        cl = base.with_(topology=topo)
        ho_off = cell(f"{tag}][ho_sgd][B=1", cl, "ho_sgd", 1)
        ho_on = cell(f"{tag}][ho_sgd][B={B}", cl, "ho_sgd", B)
        sy_off = cell(f"{tag}][sync_sgd][B=1", cl, "sync_sgd", 1)
        sy_on = cell(f"{tag}][sync_sgd][B={B}", cl, "sync_sgd", B)
        acceptance[f"ho_comm_hidden[{tag}]"] = \
            ho_on["exposed_ratio"] <= 1.05
        acceptance[f"sync_comm_exposed[{tag}]"] = \
            sy_on["exposed_ratio"] >= 1.20
        acceptance[f"bytes_invariant[{tag}]"] = (
            ho_on["bytes_total"] == ho_off["bytes_total"]
            and ho_on["comm_bytes"] == ho_off["comm_bytes"]
            and sy_on["bytes_total"] == sy_off["bytes_total"]
            and sy_on["comm_bytes"] == sy_off["comm_bytes"])

    if args.trace_report:
        trace_report(args, acceptance, results, B, tau, batch, params)

    # contention sub-axis: unbarriered ZO exchanges through shared links
    for tag, topo in topos.items():
        cl = base.with_(topology=topo, max_staleness=2, straggler_prob=0.3)
        c_on = cell(f"{tag}][ho_sgd][stale=2,contention=on", cl, "ho_sgd", 1)
        c_off = cell(f"{tag}][ho_sgd][stale=2,contention=off",
                     cl.with_(contention=False), "ho_sgd", 1)
        acceptance[f"contention_delays_only[{tag}]"] = (
            c_on["sim_seconds"] >= c_off["sim_seconds"]
            and c_on["bytes_total"] == c_off["bytes_total"])

    for k, ok in acceptance.items():
        print(f"sim/overlap_acceptance[{k}],0,{int(ok)}")

    if args.overlap_out:
        out_dir = os.path.dirname(args.overlap_out)
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
        with open(args.overlap_out, "w") as f:
            json.dump({
                "bench": "sim_frontier_overlap",
                "config": dict(buckets=B, iters=iters, tau=tau, batch=batch,
                               seed=args.seed),
                "acceptance": {k: bool(v) for k, v in acceptance.items()},
                "rows": rows,
            }, f, indent=1)
        print(f"# wrote {args.overlap_out}")

    bad = [k for k, ok in acceptance.items() if not ok]
    if bad:
        raise SystemExit(f"overlap/contention acceptance violated: {bad}")


def federated_axis(args, ds, params):
    """Federated partial-participation frontier (the ISSUE-9 acceptance
    criterion): HO-SGD with sampled-cohort rounds vs FedAvg-style
    local-update averaging vs masked FedDropoutAvg, at client populations
    N ∈ {256, 1024} and participation K/N ∈ {1%, 10%} with 90%
    availability churn.

    Every cell replays the real round programs over the seeded K-of-N
    cohort schedule (``ClusterSpec.sampling``) on a bandwidth-starved
    cluster and reports time-to-target-loss.  Acceptance:

      * determinism — the N=1024, K/N=1% fed_ho_sgd cell, run twice from
        scratch, produces a bit-identical event trace and loss history;
      * ledger-booked cohort bytes — each fed_avg round's booked wire bytes
        equal per-client model bytes × that round's LIVE cohort (the
        sampled-and-up clients of the seeded schedule), never × N;
      * every method reaches a finite loss (the frontier rows compare
        t_to_target / bytes across the three methods).

    Writes ``--federated-out`` (BENCH_sim_frontier_federated.json — rides
    the CI artifact glob).
    """
    from repro.dist.collectives import _tree_nbytes

    tau, local_steps, avail = 4, 4, 0.9
    grid = ([(256, 0.10), (1024, 0.01)] if args.smoke
            else [(256, 0.01), (256, 0.10), (1024, 0.01), (1024, 0.10)])
    iters = args.federated_iters if not args.smoke \
        else min(args.federated_iters, 40)
    methods = ["fed_ho_sgd", "fed_avg", "fed_dropout_avg"]
    rows, acceptance, results = [], {}, {}

    def cell(N, K, method):
        cl = ClusterSpec(m=K, flops_per_sec=args.flops, alpha=args.alpha,
                         bandwidth=args.bandwidth, n_clients=N, cohort_k=K,
                         availability=avail, seed=args.seed)
        batch = K * 2 * local_steps
        sm = make_sim_methods(mlp_loss, params, cl, tau=tau, lr=args.lr,
                              zo_lr=args.zo_lr, seed=args.seed,
                              local_steps=local_steps,
                              which=[method])[method]
        s = run_one(f"{method}[N={N},K={K}]", sm, params, ds, cl,
                    iters=iters, batch=batch, target=args.target_loss,
                    seed=args.seed)
        return cl, sm, s

    print("name,us_per_call," + ",".join(FIELDS))
    for N, frac in grid:
        K = max(2, int(round(N * frac)))
        for method in methods:
            cl, sm, s = cell(N, K, method)
            s.update(n_clients=N, cohort_k=K, participation=frac,
                     availability=avail, method=method)
            rows.append(s)
            results[(N, K, method)] = cl
            print(f"sim/{s['config']},0,"
                  + ",".join(fmt(s[k]) for k in FIELDS))
            acceptance[f"finite_loss[{s['config']}]"] = \
                math.isfinite(s["final_loss"])

    # determinism pin: the N>=1024, 1%-participation fed_ho_sgd cell run
    # twice from scratch must produce bit-identical traces
    N_pin, K_pin = 1024, max(2, int(round(1024 * 0.01)))
    cl = ClusterSpec(m=K_pin, flops_per_sec=args.flops, alpha=args.alpha,
                     bandwidth=args.bandwidth, n_clients=N_pin,
                     cohort_k=K_pin, availability=avail, seed=args.seed)
    batch = K_pin * 2 * local_steps
    compute = compute_model_for(params, cl, batch // cl.m)

    def run_once(method):
        sm = make_sim_methods(mlp_loss, params, cl, tau=tau, lr=args.lr,
                              zo_lr=args.zo_lr, seed=args.seed,
                              local_steps=local_steps,
                              which=[method])[method]
        return simulate(sm, params, batches(ds, batch, seed=args.seed), cl,
                        iters, compute=compute)

    r1, r2 = run_once("fed_ho_sgd"), run_once("fed_ho_sgd")
    acceptance["determinism_bit_identical_trace[N=1024,K/N=1%]"] = (
        r1.trace == r2.trace and r1.losses == r2.losses
        and r1.comm_bytes == r2.comm_bytes)

    # ledger pin: each fed_avg round's booked bytes = per-client model
    # bytes x that round's LIVE cohort from the seeded schedule (never x N)
    ra = run_once("fed_avg")
    per_client = _tree_nbytes(params)
    fed = cl.sampling
    ok = all(
        ra.comm_bytes[t] == per_client * len(fed.cohort_for(t))
        and ra.active_counts[t] == len(fed.cohort_for(t))
        for t in range(len(ra.comm_bytes)))
    acceptance["cohort_bytes_ledger_booked[fed_avg]"] = ok

    for k, v in acceptance.items():
        print(f"sim/federated_acceptance[{k}],0,{int(bool(v))}")

    if args.federated_out:
        out_dir = os.path.dirname(args.federated_out)
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
        with open(args.federated_out, "w") as f:
            json.dump({
                "bench": "sim_frontier_federated",
                "config": dict(grid=grid, iters=iters, tau=tau,
                               local_steps=local_steps, availability=avail,
                               target=args.target_loss, seed=args.seed),
                "acceptance": {k: bool(v) for k, v in acceptance.items()},
                "rows": rows,
            }, f, indent=1)
        print(f"# wrote {args.federated_out}")

    bad = [k for k, ok in acceptance.items() if not ok]
    if bad:
        raise SystemExit(f"federated acceptance violated: {bad}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="CI-sized sweep")
    ap.add_argument("--dataset", default="acoustic")
    ap.add_argument("--hidden", type=int, default=32)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--iters", type=int, default=800)
    ap.add_argument("--tau", type=int, default=8, help="base tau")
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--zo-lr", type=float, default=0.002)
    ap.add_argument("--target-loss", type=float, default=0.75)
    ap.add_argument("--bandwidth", type=float, default=1e5)
    ap.add_argument("--alpha", type=float, default=1e-5)
    ap.add_argument("--flops", type=float, default=1e9)
    ap.add_argument("--topology", default="flat",
                    choices=list(COLLECTIVE_KINDS),
                    help="all-reduce algorithm of the base cluster")
    ap.add_argument("--pods", type=int, default=1,
                    help=">1 makes the base cluster's reduce hierarchical")
    ap.add_argument("--inter-alpha", type=float, default=1e-3)
    ap.add_argument("--inter-bandwidth", type=float, default=None,
                    help="inter-pod bytes/s (default: --bandwidth / 4)")
    ap.add_argument("--max-staleness", type=int, default=0,
                    help="async staleness bound of the base cluster")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out",
                    default=os.path.join(REPO_ROOT, "BENCH_sim_frontier.json"))
    # overlap / contention axis (latency-honest rounds)
    ap.add_argument("--overlap-buckets", type=int, default=8,
                    help="bucket count for the overlap axis cells")
    ap.add_argument("--overlap-iters", type=int, default=48,
                    help="iterations per overlap-axis cell")
    ap.add_argument("--overlap-only", action="store_true",
                    help="run just the overlap/contention axis (CI step)")
    ap.add_argument("--no-overlap-axis", action="store_true",
                    help="skip the overlap/contention axis (used by the "
                         "ring2pod/gossip CI steps so it runs exactly once)")
    ap.add_argument("--overlap-out",
                    default=os.path.join(REPO_ROOT,
                                         "BENCH_sim_frontier_overlap.json"))
    # federated partial-participation axis
    ap.add_argument("--federated", action="store_true",
                    help="run just the federated K-of-N partial-"
                         "participation axis (CI step): fed_ho_sgd vs "
                         "fed_avg vs fed_dropout_avg at N in {256,1024}, "
                         "K/N in {1%%,10%%}, with determinism and "
                         "cohort-byte acceptance pins")
    ap.add_argument("--federated-iters", type=int, default=160,
                    help="iterations per federated-axis cell (smoke caps "
                         "at 40)")
    ap.add_argument("--federated-out",
                    default=os.path.join(
                        REPO_ROOT, "BENCH_sim_frontier_federated.json"))
    ap.add_argument("--trace-report", action="store_true",
                    help="export the bucketed overlap cells as Perfetto "
                         "traces and re-derive the exposed-comm headline "
                         "(ho ≤ 0.05, sync ≥ 0.2) from the trace files "
                         "alone, cross-checked against the closed forms")
    args = ap.parse_args(argv)

    taus = [2, 8] if args.smoke else [2, 4, 8, 16]
    if args.tau not in taus:        # the ordering check reads tau=args.tau
        taus = sorted(taus + [args.tau])
    ms = [4] if args.smoke else [2, 4, 8]
    codecs = ["none", "qsgd"] if args.smoke else ["none", "qsgd", "signsgd",
                                                  "topk"]
    strags = [0.0, 0.3] if args.smoke else [0.0, 0.2, 0.5]
    singles = ["sync_sgd", "zo_sgd", "ho_sgd_adaptive", "pa_sgd", "pa_gossip",
               "ri_sgd", "qsgd"]

    ds = make_classification(args.dataset, seed=args.seed)
    params = init_mlp_classifier(jax.random.key(args.seed), ds.n_features,
                                 ds.n_classes, hidden=args.hidden)
    if args.federated:
        federated_axis(args, ds, params)
        return
    if args.overlap_only:
        overlap_axis(args, ds, params)
        return
    inter_bw = (args.inter_bandwidth if args.inter_bandwidth is not None
                else args.bandwidth / 4)

    def topo(pods):
        return (Topology(pods=pods, inter_alpha=args.inter_alpha,
                         inter_bandwidth=inter_bw) if pods > 1 else None)

    base = bandwidth_constrained(m=4, bandwidth=args.bandwidth,
                                 alpha=args.alpha, flops_per_sec=args.flops,
                                 seed=args.seed, collective=args.topology,
                                 topology=topo(args.pods),
                                 max_staleness=args.max_staleness)
    mk = dict(tau=args.tau, lr=args.lr, zo_lr=args.zo_lr, seed=args.seed)
    run = dict(iters=args.iters, batch=args.batch, target=args.target_loss,
               seed=args.seed)

    rows = []
    print("name,us_per_call," + ",".join(FIELDS))

    # several sweep axes pass through the same configuration (e.g. the base
    # tau/m/codec/straggler point, or stale=0 when the base is already
    # synchronous) — memoize full simulate runs on (method, cluster, tau,
    # codec, wire mode) so each distinct configuration is simulated exactly
    # once
    memo = {}

    def emit(cfg_name, cluster, *, method="ho_sgd", tau=None, codec=None,
             wire="per_worker"):
        key = (method, cluster, tau if tau is not None else args.tau, codec,
               wire)
        s = memo.get(key)
        if s is None:
            sm = make_sim_methods(
                mlp_loss, params, cluster,
                **{**mk, "tau": key[2]},
                codec=get_compressor(codec) if codec else None,
                compress_mode=wire,
                which=[method])[method]
            s = memo[key] = run_one(cfg_name, sm, params, ds, cluster, **run)
        s = dict(s, config=cfg_name)
        rows.append(s)
        print(f"sim/{cfg_name},0," + ",".join(fmt(s[k]) for k in FIELDS))
        return s

    # tau frontier (the paper's knob) on the bandwidth-constrained cluster
    for tau in taus:
        emit(f"ho_sgd[tau={tau}]", base, tau=tau)

    # worker-count frontier (m values the pod count cannot split are
    # skipped — a 2-worker cluster has no 4-pod hierarchy)
    for m in ms:
        if m % max(1, args.pods):
            print(f"# skip ho_sgd[m={m}]: {args.pods} pods do not divide m")
            continue
        emit(f"ho_sgd[m={m}]", base.with_(m=m))

    # FO-codec frontier (wire bytes straight from the ledger's booked codec)
    # — the compress-mode axis shows the HONEST per-worker QSGD byte cost
    # (nbytes x m, each worker receives every worker's code) next to the
    # legacy post-reduction accounting
    for codec in codecs:
        emit(f"ho_sgd[codec={codec}]", base,
             codec=None if codec == "none" else codec)
        if codec != "none":
            emit(f"ho_sgd[codec={codec},wire=legacy]", base, codec=codec,
                 wire="legacy")

    # straggler severity frontier
    for p in strags:
        emit(f"ho_sgd[strag={p}]", base.with_(straggler_prob=p))

    # topology frontier: HO vs sync under each all-reduce algorithm and a
    # 2-pod hierarchical reduce — the Table-1 ordering must survive
    # non-flat links (the regime where model-averaging baselines look
    # artificially close on a flat switch)
    topo_axes = ([("ring", 1), ("ring", 2)] if args.smoke
                 else [("flat", 1), ("ring", 1), ("tree", 1), ("gossip", 1),
                       ("ring", 2), ("tree", 2)])
    topo_ok = {}
    for kind, pods in topo_axes:
        cl = base.with_(collective=kind, topology=topo(pods))
        tag = f"{kind}" + (f"+{pods}pod" if pods > 1 else "")
        s_ho = emit(f"ho_sgd[topo={tag}]", cl)
        s_sy = emit(f"sync_sgd[topo={tag}]", cl, method="sync_sgd")
        topo_ok[tag] = s_ho["t_to_target"] < s_sy["t_to_target"]

    # async staleness frontier (ZO rounds unbarriered; FO syncs barriered)
    stales = [0, 2] if args.smoke else [0, 1, 2, 4]
    for s in stales:
        emit(f"ho_sgd[stale={s}]",
             base.with_(max_staleness=s, straggler_prob=0.3))

    # elastic membership: failures shrink W, rejoins restore via checkpoint
    emit("ho_sgd[elastic]",
         base.with_(elastic=True, fail_rate=2.0, downtime=0.5,
                    restart_time=0.05))

    # the baselines at the base configuration (QSGD additionally under the
    # legacy post-reduction byte accounting, for the honest-vs-legacy gap)
    by_name = {name: emit(name, base, method=name) for name in singles}
    emit("qsgd[wire=legacy]", base, method="qsgd", wire="legacy")

    # the acceptance ordering (paper Table 1, on simulated wall-clock)
    ho = next(r for r in rows if r["config"] == f"ho_sgd[tau={args.tau}]")
    ok_sync = ho["t_to_target"] < by_name["sync_sgd"]["t_to_target"]
    ok_zo = (ho["feval_s_to_target"]
             < by_name["zo_sgd"]["feval_s_to_target"])
    print(f"sim/ordering_ho_beats_sync_wallclock,0,{int(ok_sync)}")
    print(f"sim/ordering_ho_beats_zo_feval_seconds,0,{int(ok_zo)}")
    for tag, ok in topo_ok.items():
        print(f"sim/ordering_ho_beats_sync[{tag}],0,{int(ok)}")

    if args.out:
        out_dir = os.path.dirname(args.out)
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
        with open(args.out, "w") as f:
            json.dump({
                "bench": "sim_frontier",
                "config": {k: v for k, v in vars(args).items()},
                "orderings": {
                    "ho_beats_sync_wallclock": bool(ok_sync),
                    "ho_beats_zo_feval_seconds": bool(ok_zo),
                    **{f"ho_beats_sync[{tag}]": bool(ok)
                       for tag, ok in topo_ok.items()},
                },
                "rows": rows,
            }, f, indent=1)
        print(f"# wrote {args.out}")

    if not (ok_sync and ok_zo and all(topo_ok.values())):
        bad_topo = [tag for tag, ok in topo_ok.items() if not ok]
        raise SystemExit(
            f"qualitative ordering violated: ho<sync={ok_sync} "
            f"ho<zo(feval_s)={ok_zo} topo_violations={bad_topo}")

    if not args.no_overlap_axis:
        overlap_axis(args, ds, params)


if __name__ == "__main__":
    main()
