"""Time-to-target-loss frontier on the simulated cluster (repro.sim).

Sweeps tau, m, the FO codec, and straggler severity; every configuration
replays the REAL step functions through the discrete-event cluster model
and reports when (in simulated seconds) it reaches the target loss.  This
is the paper's Table-1 tradeoff collapsed onto one axis — and the
benchmark asserts the qualitative ordering on a bandwidth-constrained
cluster:

  * HO-SGD reaches the target in fewer simulated seconds than sync-SGD
    (the FO exchange amortized over tau), and
  * in fewer function-evaluation-seconds than ZO-only SGD (the FO anchor
    steps do the heavy lifting).

CSV rows: ``sim/<config>,us_per_call,t_to_target,feval_s_to_target,...``
plus a BENCH json dump (``--out``) with the full per-config summaries.
"""
from __future__ import annotations

import argparse
import json
import math
import os

import jax

from repro.data.synthetic import batches, make_classification
from repro.dist import get_compressor
from repro.models.mlp import init_mlp_classifier, mlp_loss
from repro.sim import bandwidth_constrained, compute_model_for, make_sim_methods, simulate

FIELDS = ["t_to_target", "feval_s_to_target", "iters", "sim_seconds",
          "comm_s", "compute_s", "failures", "final_loss"]


def run_one(name, sm, params, ds, cluster, *, iters, batch, target, seed):
    compute = compute_model_for(params, cluster, batch // cluster.m)
    eval_batch = {"x": ds.x_test, "y": ds.y_test}
    eval_fn = jax.jit(lambda p: mlp_loss(p, eval_batch))
    res = simulate(sm, params, batches(ds, batch, seed=seed), cluster, iters,
                   compute=compute, eval_fn=eval_fn, eval_every=1,
                   target_loss=target)
    s = res.summary()
    s["t_to_target"] = res.time_to_loss(target)
    s["feval_s_to_target"] = res.feval_seconds_to_loss(target)
    s["config"] = name
    return s


def fmt(v):
    if isinstance(v, float):
        return "inf" if math.isinf(v) else f"{v:.6g}"
    return str(v)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="CI-sized sweep")
    ap.add_argument("--dataset", default="acoustic")
    ap.add_argument("--hidden", type=int, default=32)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--iters", type=int, default=800)
    ap.add_argument("--tau", type=int, default=8, help="base tau")
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--zo-lr", type=float, default=0.002)
    ap.add_argument("--target-loss", type=float, default=0.75)
    ap.add_argument("--bandwidth", type=float, default=1e5)
    ap.add_argument("--alpha", type=float, default=1e-5)
    ap.add_argument("--flops", type=float, default=1e9)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="artifacts/sim/frontier.json")
    args = ap.parse_args(argv)

    taus = [2, 8] if args.smoke else [2, 4, 8, 16]
    if args.tau not in taus:        # the ordering check reads tau=args.tau
        taus = sorted(taus + [args.tau])
    ms = [4] if args.smoke else [2, 4, 8]
    codecs = ["none", "qsgd"] if args.smoke else ["none", "qsgd", "signsgd",
                                                  "topk"]
    strags = [0.0, 0.3] if args.smoke else [0.0, 0.2, 0.5]
    singles = ["sync_sgd", "zo_sgd", "ho_sgd_adaptive", "pa_sgd", "ri_sgd",
               "qsgd"]

    ds = make_classification(args.dataset, seed=args.seed)
    params = init_mlp_classifier(jax.random.key(args.seed), ds.n_features,
                                 ds.n_classes, hidden=args.hidden)
    base = bandwidth_constrained(m=4, bandwidth=args.bandwidth,
                                 alpha=args.alpha, flops_per_sec=args.flops,
                                 seed=args.seed)
    mk = dict(tau=args.tau, lr=args.lr, zo_lr=args.zo_lr, seed=args.seed)
    run = dict(iters=args.iters, batch=args.batch, target=args.target_loss,
               seed=args.seed)

    rows = []
    print("name,us_per_call," + ",".join(FIELDS))

    def emit(cfg_name, sm, cluster):
        s = run_one(cfg_name, sm, params, ds, cluster, **run)
        rows.append(s)
        print(f"sim/{cfg_name},0," + ",".join(fmt(s[k]) for k in FIELDS))
        return s

    # tau frontier (the paper's knob) on the bandwidth-constrained cluster
    for tau in taus:
        sm = make_sim_methods(mlp_loss, params, base, **{**mk, "tau": tau},
                              which=["ho_sgd"])["ho_sgd"]
        emit(f"ho_sgd[tau={tau}]", sm, base)

    # worker-count frontier
    for m in ms:
        cl = base.with_(m=m)
        sm = make_sim_methods(mlp_loss, params, cl, **mk,
                              which=["ho_sgd"])["ho_sgd"]
        emit(f"ho_sgd[m={m}]", sm, cl)

    # FO-codec frontier (wire bytes straight from the ledger's booked codec)
    for codec in codecs:
        sm = make_sim_methods(mlp_loss, params, base, **mk,
                              codec=get_compressor(codec),
                              which=["ho_sgd"])["ho_sgd"]
        emit(f"ho_sgd[codec={codec}]", sm, base)

    # straggler severity frontier
    for p in strags:
        cl = base.with_(straggler_prob=p)
        sm = make_sim_methods(mlp_loss, params, cl, **mk,
                              which=["ho_sgd"])["ho_sgd"]
        emit(f"ho_sgd[strag={p}]", sm, cl)

    # the baselines at the base configuration
    by_name = {}
    sims = make_sim_methods(mlp_loss, params, base, **mk, which=singles)
    for name, sm in sims.items():
        by_name[name] = emit(name, sm, base)

    # the acceptance ordering (paper Table 1, on simulated wall-clock)
    ho = next(r for r in rows if r["config"] == f"ho_sgd[tau={args.tau}]")
    ok_sync = ho["t_to_target"] < by_name["sync_sgd"]["t_to_target"]
    ok_zo = (ho["feval_s_to_target"]
             < by_name["zo_sgd"]["feval_s_to_target"])
    print(f"sim/ordering_ho_beats_sync_wallclock,0,{int(ok_sync)}")
    print(f"sim/ordering_ho_beats_zo_feval_seconds,0,{int(ok_zo)}")

    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump({
                "bench": "sim_frontier",
                "config": {k: v for k, v in vars(args).items()},
                "orderings": {"ho_beats_sync_wallclock": bool(ok_sync),
                              "ho_beats_zo_feval_seconds": bool(ok_zo)},
                "rows": rows,
            }, f, indent=1)
        print(f"# wrote {args.out}")

    if not (ok_sync and ok_zo):
        raise SystemExit(
            f"qualitative ordering violated: ho<sync={ok_sync} "
            f"ho<zo(feval_s)={ok_zo}")


if __name__ == "__main__":
    main()
