"""Paper Fig. 1 + Table 2: universal adversarial example generation.

Compares HO-SGD to syncSGD / RI-SGD / ZO-SGD / ZO-SVRG-Ave on the attack
loss (d = 900, m = 5, B = 5, step-size 30/d — the paper's exact setup) and
reports the final attack loss and l2 distortion per method."""
from __future__ import annotations

import time
from typing import Dict

import jax
import numpy as np

from repro.apps.attack import attack_metrics, make_attack_loss, train_victim
from repro.core import (
    HOSGDConfig, make_ho_sgd, make_ri_sgd, make_sync_sgd, make_zo_sgd,
    make_zo_svrg_ave,
)
from repro.data.synthetic import make_digits


def run(n_iters: int = 300, n_images: int = 10, m: int = 5, B: int = 5,
        tau: int = 8, seed: int = 0, verbose: bool = True) -> Dict[str, Dict]:
    d = 900
    victim, victim_acc = train_victim(jax.random.key(seed))
    loss_fn, z_of = make_attack_loss(victim, c=5.0)

    # n images from the same class (paper setup); batches resample them.
    # seed=1 matches the victim's training distribution, and the pool keeps
    # only correctly-classified images (standard attack protocol).
    from repro.models.mlp import mlp_logits
    import jax.numpy as jnp
    x, y = make_digits(n=4096, seed=1)
    preds = np.asarray(jnp.argmax(mlp_logits(victim, jnp.asarray(x)), -1))
    x, y = x[preds == y], y[preds == y]
    cls = int(np.bincount(y).argmax())
    pool_x, pool_y = x[y == cls][: 4 * n_images], y[y == cls][: 4 * n_images]

    def data(seed_):
        rng = np.random.default_rng(seed_)
        while True:
            idx = rng.integers(0, len(pool_x), size=m * B)
            yield {"a": pool_x[idx], "y": pool_y[idx]}

    lr = 30.0 / d                 # the paper's constant step size
    mu = 1.0 / np.sqrt(d * n_iters)  # mu = O(1/sqrt(dN))
    params0 = {"x": jax.numpy.zeros((d,))}
    anchor = {"a": pool_x, "y": pool_y}
    methods = {
        "ho_sgd": make_ho_sgd(loss_fn, HOSGDConfig(tau=tau, mu=mu, m=m, lr=lr)),
        "sync_sgd": make_sync_sgd(loss_fn, m, lr=lr),
        "ri_sgd": make_ri_sgd(loss_fn, m, tau=tau, lr=lr, mu_r=0.25),
        "zo_sgd": make_zo_sgd(loss_fn, m, mu=mu, lr=lr),
        "zo_svrg_ave": make_zo_svrg_ave(loss_fn, m, mu=mu, lr=lr,
                                        dataset=anchor, epoch_len=50),
    }
    # note: ZO steps here use the same 30/d step size as FO steps, exactly as
    # in the paper's §5.1 (d=900 is small enough that it is stable)

    results = {}
    key = jax.random.key(seed)
    eval_batch = {"a": pool_x[:n_images], "y": pool_y[:n_images]}
    base = attack_metrics(victim, z_of, params0, eval_batch["a"], eval_batch["y"])
    if verbose:
        print(f"# victim accuracy: {victim_acc:.3f}; x=0 attack success "
              f"(sanity, should be ~0): {base['success_rate']:.2f}")
    for name, meth in methods.items():
        params, state = params0, meth.init(params0)
        losses = []
        t0 = time.perf_counter()
        it = data(seed + 1)
        for t in range(n_iters):
            params, state, metrics = meth.step(t, params, state, next(it), key)
            losses.append(float(metrics["loss"]))
        am = attack_metrics(victim, z_of, params, eval_batch["a"], eval_batch["y"])
        results[name] = {
            "loss_curve": losses,
            "final_loss": float(np.mean(losses[-10:])),
            "wall_s": time.perf_counter() - t0,
            "us_per_call": 1e6 * (time.perf_counter() - t0) / n_iters,
            **am,
        }
        if verbose:
            print(f"{name:12s} final_loss={results[name]['final_loss']:.4f} "
                  f"l2={am['l2_all']:.3f} success={am['success_rate']:.2f} "
                  f"({results[name]['wall_s']:.1f}s)")
    return results


def main():
    print("name,us_per_call,final_attack_loss,l2_distortion,success_rate")
    for name, r in run().items():
        print(f"fig1/{name},{r['us_per_call']:.1f},{r['final_loss']:.4f},"
              f"{r['l2_all']:.3f},{r['success_rate']:.2f}")


if __name__ == "__main__":
    main()
