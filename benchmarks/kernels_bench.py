"""Kernel micro-benchmarks: Pallas (interpret) vs jnp oracle.

On this CPU container interpret-mode timing measures Python dispatch, not
TPU performance — the number that matters for the roofline is the HBM-bytes
model printed per kernel (what the fused kernel reads/writes vs the jnp
path; see kernels/*.py docstrings and EXPERIMENTS.md §Perf).

Emits root-level ``BENCH_kernels.json`` (``--out``) so the kernel perf
trajectory is tracked like the sim/serve frontiers: per-kernel rows plus the
per-engine ZO-round comparison (step time, direction-bytes model, kernel
launches per round, and HBM passes over d for the reconstruct→optimizer
commit phase — the axis the ``flat`` backend collapses from 4 to 2).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def timeit(fn, *args, reps=3):
    jax.block_until_ready(fn(*args))        # one warmup dispatch (compile)
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return 1e6 * (time.perf_counter() - t0) / reps


def engine_compare(smoke: bool = False):
    """End-to-end ZO step per DirectionEngine backend: step time + the
    direction-algebra HBM-bytes model.

    The bytes column counts only traffic for handling the direction vector
    (the loss evaluations are identical across backends), fp32, d params,
    m workers, per ZO step:

    * tree   — v materialized per use: perturb m*(v write + v read + x
               read + x~ write) = 16*d*m; reconstruct m*(v write + v read +
               acc read + acc write) = 16*d*m.
    * fused  — generation fused into the consuming op (no v buffer):
               perturb m*(x read + x~ write) = 8*d*m; reconstruct
               acc kept live through the worker loop = 8*d*m.
    * pallas — perturb m*(x read + x~ write) = 8*d*m; reconstruct all m
               workers in one pass = one 4*d write (acc in registers).
    * flat   — perturb m*(x read + x~ write) = 8*d*m (the tree-wide sumsq
               accumulates in the same grid, so the separate inv-norm pass
               disappears); the reconstructed update never exists in HBM —
               it goes straight into the in-kernel SGD commit.

    Two more roofline axes, per ZO round (m workers, L leaves, no momentum):

    * ``kernel_launches`` — pallas launches one kernel per leaf per perturb
      plus one per leaf for reconstruct = L*(m+1); flat launches one kernel
      per perturb plus one fused commit = m+1; tree/fused launch none (pure
      XLA programs, counted 0).
    * ``hbm_passes_over_d_commit`` — d-sized buffer passes in the
      reconstruct→optimizer-commit phase: unfused backends write the update
      (1), the optimizer reads it (1) and reads+writes params (2) = 4
      (momentum adds 2 more); flat reads+writes params once in the commit
      kernel = 2 (momentum rides the same launch).

    On this CPU container interpret-mode timing measures dispatch, not TPU
    performance — the bytes model is the roofline-relevant number; the
    timings only sanity-check that every backend drives the identical
    optimizer step.
    """
    from repro.core.ho_sgd import HOSGDConfig, make_ho_sgd

    d_leaf = (1 << 12) + 321 if smoke else (1 << 18) + 321  # odd: tail blocks
    m, B = 4, 8
    params = {"w": jax.random.normal(jax.random.key(1), (d_leaf,)),
              "b": jax.random.normal(jax.random.key(2), (257,))}
    d = d_leaf + 257
    n_leaves = len(jax.tree.leaves(params))

    def loss_fn(p, b):
        return 0.5 * jnp.mean(jnp.sum((p["w"][None, :] - b["t"]) ** 2, -1)) \
            + 0.5 * jnp.sum(p["b"] ** 2)

    batch = {"t": jax.random.normal(jax.random.key(3), (m * B, d_leaf))}
    bytes_model = {
        "tree": 32 * d * m,
        "fused": 16 * d * m,
        "pallas": 8 * d * m + 4 * d,
        "flat": 8 * d * m,
    }
    launches = {
        "tree": 0,
        "fused": 0,
        "pallas": n_leaves * (m + 1),
        "flat": m + 1,
    }
    commit_passes = {"tree": 4, "fused": 4, "pallas": 4, "flat": 2}
    rows = []
    print("engine,us_per_zo_step,direction_bytes_model,kernel_launches,"
          "hbm_passes_over_d_commit,loss")
    for name in ("tree", "fused", "pallas", "flat"):
        cfg = HOSGDConfig(tau=1 << 30, mu=1e-3, m=m, lr=0.05, zo_lr=0.05 / d,
                          engine=name)
        meth = make_ho_sgd(loss_fn, cfg)
        state = meth.init(params)

        def one_step(p, s):
            p, s, metrics = meth.step(1, p, s, batch)
            return p, s, metrics["loss"]

        p1, s1, loss = one_step(params, state)          # compile + warm
        t0 = time.perf_counter()
        reps = 2 if smoke else 5
        for _ in range(reps):
            _, _, l = one_step(params, state)
        jax.block_until_ready(l)
        us = 1e6 * (time.perf_counter() - t0) / reps
        print(f"engine/{name},{us:.0f},{bytes_model[name]},{launches[name]},"
              f"{commit_passes[name]},{float(loss):.6f}")
        rows.append({
            "engine": name,
            "us_per_zo_step": us,
            "direction_bytes_model": bytes_model[name],
            "kernel_launches_per_zo_round": launches[name],
            "hbm_passes_over_d_commit": commit_passes[name],
            "loss": float(loss),
        })
    return {"d": d, "m": m, "n_leaves": n_leaves, "momentum": 0.0,
            "engines": rows}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes / few reps (CI tier-2)")
    ap.add_argument("--out", default=os.path.join(ROOT, "BENCH_kernels.json"),
                    help="BENCH json output path ('' disables)")
    args = ap.parse_args(argv)
    smoke = args.smoke

    key = jax.random.key(0)
    kernel_rows = []

    def row(name, us, hbm_kernel, hbm_jnp):
        print(f"{name},{us:.0f},{hbm_kernel},{hbm_jnp}")
        kernel_rows.append({"name": name, "us_per_call": us,
                            "hbm_bytes_kernel": hbm_kernel,
                            "hbm_bytes_jnp": hbm_jnp})

    print("name,us_per_call,hbm_bytes_kernel,hbm_bytes_jnp")

    # rmsnorm: kernel reads x + writes y; jnp identical (fused either way)
    x = jax.random.normal(key, (2048, 1024))
    s = jnp.ones((1024,))
    nb = x.size * 4 * 2
    row("kern/rmsnorm", timeit(lambda a, b: ops.rmsnorm(a, b), x, s), nb, nb)

    # flash attention: kernel never materializes (S,S) probs
    B, S, H, hd = 1, (128 if smoke else 512), 4, 64
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, hd))
    t = timeit(lambda a, b, c: ops.flash_attention(a, b, c, block_q=128,
                                                   block_k=128), q, k, v)
    io = 4 * B * S * H * hd * 4
    probs = B * H * S * S * 4
    row("kern/flash_attention", t, io, io + 2 * probs)

    # selective scan: kernel keeps (di, n) state in VMEM; jnp materializes
    # (B, S, di, n) twice (deltaA, deltaBu) plus the scanned h
    B, S, di, n = 2, (64 if smoke else 256), (64 if smoke else 256), 16
    u = jax.random.normal(key, (B, S, di)) * 0.3
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 3), (B, S, di))) * 0.1
    Bm = jax.random.normal(jax.random.fold_in(key, 4), (B, S, n))
    Cm = jax.random.normal(jax.random.fold_in(key, 5), (B, S, n))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 6), (di, n)) * 0.2)
    Dp = jnp.ones((di,))
    t = timeit(lambda *a: ops.selective_scan(*a, block_d=128, block_s=128),
               u, dt, Bm, Cm, A, Dp)
    io = (3 * B * S * di + 2 * B * S * n) * 4
    state4d = 3 * B * S * di * n * 4
    row("kern/selective_scan", t, io, io + state4d)

    # zo perturb: kernel = 1 read + 1 write of x (direction never in HBM);
    # jnp path additionally writes+reads the direction.  Odd size: the tail
    # block exercises the masked-boundary path.
    npar = (1 << 14) + 321 if smoke else (1 << 20) + 321
    xx = jax.random.normal(key, (npar,))
    t = timeit(lambda a: ops.zo_perturb(a, 55, 0.01, 0, block=8192), xx)
    row("kern/zo_perturb", t, npar * 4 * 2, npar * 4 * 4)

    # zo reconstruct (m=8): kernel = 1 write; jnp = m reads + m writes
    m = 8
    salts = jnp.arange(m, dtype=jnp.uint32)
    coeffs = jnp.linspace(-1, 1, m, dtype=jnp.float32)
    t = timeit(lambda s_, c_: ops.zo_reconstruct(npar, s_, c_, 0, block=8192),
               salts, coeffs)
    row("kern/zo_reconstruct", t, npar * 4, npar * 4 * 2 * m)

    # flat multi-leaf kernels on a block-aligned packed buffer: perturb+sumsq
    # is one launch = 1 read + 1 write of x (the inv-norm pass over d is
    # gone — jnp pays an extra generate+reduce read-equivalent); the fused
    # reconstruct+SGD commit is 1 read + 1 write of params with the update
    # never materialized (jnp: update write + update read + params
    # read/write).
    block = 8192
    nblk = -(-npar // block)
    pad = nblk * block - npar
    xflat = jnp.pad(xx, (0, pad))
    bsalts = jnp.full((nblk,), 55, jnp.uint32)
    ctrs = (jnp.arange(nblk, dtype=jnp.uint32) * block)
    nvalid = jnp.minimum(block, npar - jnp.arange(nblk) * block).astype(jnp.int32)
    t = timeit(lambda a: ops.zo_perturb_sumsq(a, bsalts, ctrs, nvalid, 1e-3,
                                              block=block), xflat)
    row("kern/zo_perturb_sumsq", t, npar * 4 * 2, npar * 4 * 3)

    msalts = jnp.tile(salts[None, :], (nblk, 1))
    bf16 = jnp.zeros((nblk,), jnp.int32)
    # the params buffer is DONATED (updated in place) — hand the kernel a
    # fresh copy per call so timing iterations don't reuse a deleted buffer
    t = timeit(
        lambda a, c_: ops.zo_reconstruct_update(
            a.copy(), None, msalts, ctrs, nvalid, bf16, c_, 0.05,
            block=block)[0],
        xflat, coeffs)
    row("kern/zo_reconstruct_update", t, npar * 4 * 2, npar * 4 * 4)

    zo_round = engine_compare(smoke)

    if args.out:
        payload = {
            "generated_by": "benchmarks/kernels_bench.py",
            "smoke": smoke,
            "backend": jax.default_backend(),
            "interpret": bool(ops.INTERPRET),
            "kernels": kernel_rows,
            "zo_round": zo_round,
        }
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
