"""Kernel micro-benchmarks: Pallas (interpret) vs jnp oracle.

On this CPU container interpret-mode timing measures Python dispatch, not
TPU performance — the number that matters for the roofline is the HBM-bytes
model printed per kernel (what the fused kernel reads/writes vs the jnp
path; see kernels/*.py docstrings and EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def timeit(fn, *args, reps=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return 1e6 * (time.perf_counter() - t0) / reps


def engine_compare(smoke: bool = False):
    """End-to-end ZO step per DirectionEngine backend: step time + the
    direction-algebra HBM-bytes model.

    The bytes column counts only traffic for handling the direction vector
    (the loss evaluations are identical across backends), fp32, d params,
    m workers, per ZO step:

    * tree   — v materialized per use: perturb m*(v write + v read + x
               read + x~ write) = 16*d*m; reconstruct m*(v write + v read +
               acc read + acc write) = 16*d*m.
    * fused  — generation fused into the consuming op (no v buffer):
               perturb m*(x read + x~ write) = 8*d*m; reconstruct
               acc kept live through the worker loop = 8*d*m.
    * pallas — perturb m*(x read + x~ write) = 8*d*m; reconstruct all m
               workers in one pass = one 4*d write (acc in registers).

    On this CPU container interpret-mode timing measures dispatch, not TPU
    performance — the bytes model is the roofline-relevant number; the
    timings only sanity-check that every backend drives the identical
    optimizer step.
    """
    from repro.core.ho_sgd import HOSGDConfig, make_ho_sgd

    d_leaf = (1 << 12) + 321 if smoke else (1 << 18) + 321  # odd: tail blocks
    m, B = 4, 8
    params = {"w": jax.random.normal(jax.random.key(1), (d_leaf,)),
              "b": jax.random.normal(jax.random.key(2), (257,))}
    d = d_leaf + 257

    def loss_fn(p, b):
        return 0.5 * jnp.mean(jnp.sum((p["w"][None, :] - b["t"]) ** 2, -1)) \
            + 0.5 * jnp.sum(p["b"] ** 2)

    batch = {"t": jax.random.normal(jax.random.key(3), (m * B, d_leaf))}
    bytes_model = {
        "tree": 32 * d * m,
        "fused": 16 * d * m,
        "pallas": 8 * d * m + 4 * d,
    }
    print("engine,us_per_zo_step,direction_bytes_model,loss")
    for name in ("tree", "fused", "pallas"):
        cfg = HOSGDConfig(tau=1 << 30, mu=1e-3, m=m, lr=0.05, zo_lr=0.05 / d,
                          engine=name)
        meth = make_ho_sgd(loss_fn, cfg)
        state = meth.init(params)

        def one_step(p, s):
            p, s, metrics = meth.step(1, p, s, batch)
            return p, s, metrics["loss"]

        p1, s1, loss = one_step(params, state)          # compile + warm
        t0 = time.perf_counter()
        reps = 2 if smoke else 5
        for _ in range(reps):
            _, _, l = one_step(params, state)
        jax.block_until_ready(l)
        us = 1e6 * (time.perf_counter() - t0) / reps
        print(f"engine/{name},{us:.0f},{bytes_model[name]},{float(loss):.6f}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes / few reps (CI tier-2)")
    args = ap.parse_args(argv)
    smoke = args.smoke

    key = jax.random.key(0)
    print("name,us_per_call,hbm_bytes_kernel,hbm_bytes_jnp")

    # rmsnorm: kernel reads x + writes y; jnp identical (fused either way)
    x = jax.random.normal(key, (2048, 1024))
    s = jnp.ones((1024,))
    nb = x.size * 4 * 2
    print(f"kern/rmsnorm,{timeit(lambda a, b: ops.rmsnorm(a, b), x, s):.0f},{nb},{nb}")

    # flash attention: kernel never materializes (S,S) probs
    B, S, H, hd = 1, (128 if smoke else 512), 4, 64
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, hd))
    t = timeit(lambda a, b, c: ops.flash_attention(a, b, c, block_q=128,
                                                   block_k=128), q, k, v)
    io = 4 * B * S * H * hd * 4
    probs = B * H * S * S * 4
    print(f"kern/flash_attention,{t:.0f},{io},{io + 2 * probs}")

    # selective scan: kernel keeps (di, n) state in VMEM; jnp materializes
    # (B, S, di, n) twice (deltaA, deltaBu) plus the scanned h
    B, S, di, n = 2, (64 if smoke else 256), (64 if smoke else 256), 16
    u = jax.random.normal(key, (B, S, di)) * 0.3
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 3), (B, S, di))) * 0.1
    Bm = jax.random.normal(jax.random.fold_in(key, 4), (B, S, n))
    Cm = jax.random.normal(jax.random.fold_in(key, 5), (B, S, n))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 6), (di, n)) * 0.2)
    Dp = jnp.ones((di,))
    t = timeit(lambda *a: ops.selective_scan(*a, block_d=128, block_s=128),
               u, dt, Bm, Cm, A, Dp)
    io = (3 * B * S * di + 2 * B * S * n) * 4
    state4d = 3 * B * S * di * n * 4
    print(f"kern/selective_scan,{t:.0f},{io},{io + state4d}")

    # zo perturb: kernel = 1 read + 1 write of x (direction never in HBM);
    # jnp path additionally writes+reads the direction.  Odd size: the tail
    # block exercises the masked-boundary path.
    npar = (1 << 14) + 321 if smoke else (1 << 20) + 321
    xx = jax.random.normal(key, (npar,))
    t = timeit(lambda a: ops.zo_perturb(a, 55, 0.01, 0, block=8192), xx)
    print(f"kern/zo_perturb,{t:.0f},{npar * 4 * 2},{npar * 4 * 4}")

    # zo reconstruct (m=8): kernel = 1 write; jnp = m reads + m writes
    m = 8
    salts = jnp.arange(m, dtype=jnp.uint32)
    coeffs = jnp.linspace(-1, 1, m, dtype=jnp.float32)
    t = timeit(lambda s_, c_: ops.zo_reconstruct(npar, s_, c_, 0, block=8192),
               salts, coeffs)
    print(f"kern/zo_reconstruct,{t:.0f},{npar * 4},{npar * 4 * 2 * m}")

    engine_compare(smoke)


if __name__ == "__main__":
    main()
