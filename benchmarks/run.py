"""Benchmark orchestrator — one section per paper table/figure.

Prints ``name,us_per_call,derived...`` CSV rows.  Sections:
  table1  — conv-order / comm / compute columns (analytic, Table 1)
  comm    — measured (CommLedger) vs analytic communication curves
            across tau and the FO-compressor zoo
  fig1    — adversarial-example generation (measured, Fig 1 + Table 2)
  fig2    — multiclass MLP training (measured, Fig 2)
  kernels — Pallas kernel micro-benches + HBM-byte models, plus the
            per-engine ZO-round comparison (launch counts, commit-phase
            HBM passes over d); emits root-level BENCH_kernels.json
  roofline— dry-run derived roofline terms (if artifacts exist)
  sim     — time-to-target-loss frontier on the simulated cluster
            (tau/m/straggler/topology axes plus the compress-mode axis:
            per-worker vs legacy QSGD wire accounting, plus the
            overlap/contention axis — latency-honest rounds,
            BENCH_sim_frontier_overlap.json)
  serve   — serving frontier: continuous batching vs the seed synchronous
            batch path under open-loop Poisson traffic (slots x rate x
            arch; tok/s + p50/p99 TTFT/latency, BENCH_serve.json)

``--quick`` trims iteration counts for CI-speed runs.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None,
                    choices=["table1", "fig1", "fig2", "kernels", "roofline",
                             "tau", "comm", "sim", "serve"])
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    sections = args.only or ["table1", "comm", "kernels", "fig1", "fig2",
                             "tau", "sim", "serve", "roofline"]
    failed = []

    for sec in sections:
        print(f"\n# === {sec} ===")
        try:
            if sec == "table1":
                from benchmarks import table1
                table1.main()
            elif sec == "comm":
                from benchmarks import comm_curves
                comm_curves.main(
                    ["--d", "1024", "--iters", "8"] if args.quick else [])
            elif sec == "fig1":
                from benchmarks import fig1_attack
                if args.quick:
                    print("name,us_per_call,final_attack_loss,l2_distortion,success_rate")
                    for name, r in fig1_attack.run(n_iters=60, verbose=False).items():
                        print(f"fig1/{name},{r['us_per_call']:.1f},"
                              f"{r['final_loss']:.4f},{r['l2_all']:.3f},"
                              f"{r['success_rate']:.2f}")
                else:
                    fig1_attack.main()
            elif sec == "fig2":
                from benchmarks import fig2_classification
                argv2 = (["--iters", "30", "--hidden", "128",
                          "--datasets", "acoustic",
                          "--methods", "ho_sgd", "sync_sgd", "zo_sgd"]
                         if args.quick else ["--iters", "60"])
                fig2_classification.main(argv2)
            elif sec == "kernels":
                from benchmarks import kernels_bench
                # explicit argv: never let the section parse run.py's own flags
                kernels_bench.main(["--smoke"] if args.quick else [])
            elif sec == "tau":
                from benchmarks import tau_ablation
                tau_ablation.main(
                    ["--iters", "40", "--hidden", "128"] if args.quick
                    else ["--iters", "100"])
            elif sec == "roofline":
                from benchmarks import roofline
                roofline.main([])
            elif sec == "sim":
                from benchmarks import sim_frontier
                sim_frontier.main(["--smoke"] if args.quick else [])
            elif sec == "serve":
                from benchmarks import serve_bench
                serve_bench.main(["--smoke"] if args.quick else [])
        except Exception:
            failed.append(sec)
            traceback.print_exc()
    if failed:
        print(f"\nFAILED sections: {failed}", file=sys.stderr)
        raise SystemExit(1)
    print("\n# all benchmark sections completed")


if __name__ == "__main__":
    main()
