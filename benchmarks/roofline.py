"""Roofline analysis from the dry-run artifacts (deliverable g).

Reads artifacts/dryrun/*.json (written by repro.launch.dryrun) and derives,
per (arch x shape x mesh x step):

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s        [s]
  memory term     = HLO_bytes_per_device / HBM_bw             [s]
  collective term = collective_bytes_per_device / (links*bw)  [s]

cost_analysis() of an SPMD module reports *per-device* numbers, so the
per-chip roofline divides by per-chip peaks directly.  The dominant term is
the bottleneck the §Perf loop iterates on.  Also prints MODEL_FLOPS =
6*N_active*D (train) or 2*N_active*D (inference) and its ratio to compiled
FLOPs (remat / redundant-compute diagnostic).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from typing import Dict, List

from repro.launch.mesh import HW

# v5e: 4 ICI links/chip usable for concurrent transfers on a 2D torus
ICI_LINKS = 4


def worker_axis_bytes(rec: Dict) -> float:
    """Inter-worker collective bytes (the traffic the paper optimizes).

    In the scanned production program the gradient all-reduce over the
    worker axes sits *outside* the layer scan but *inside* the microbatch-
    accumulation scan, so the raw (body-counted-once) parse captures its
    full per-microbatch size and undercounts by exactly grad_accum.  The
    axis classification comes from the raw full-model parse (the depth-point
    unrolled lowerings re-encode replica groups differently).
    """
    from repro.configs import get_config
    raw = rec.get("collectives_raw", {}).get("axis_worker", 0.0)
    mult = 1.0
    if rec.get("step") == "fo":
        try:
            mult = float(get_config(rec["arch"]).grad_accum)
        except Exception:
            mult = 1.0
    return raw * mult


def roofline_terms(rec: Dict) -> Dict[str, float]:
    chips = 512 if rec["mesh"] == "multipod" else 256
    flops = rec["cost"]["flops"]                # per-device
    bytes_ = rec["cost"]["bytes"]
    wb = worker_axis_bytes(rec)
    coll = max(rec["collectives"]["total"], wb)
    t_compute = flops / HW["peak_flops_bf16"]
    t_memory = bytes_ / HW["hbm_bw"]
    t_coll = coll / (ICI_LINKS * HW["ici_bw"])
    dom = max(("compute", t_compute), ("memory", t_memory),
              ("collective", t_coll), key=lambda kv: kv[1])[0]
    mf = rec.get("model_flops", 0.0) / chips    # per-device model flops
    return {
        "t_compute": t_compute,
        "t_memory": t_memory,
        "t_collective": t_coll,
        "worker_bytes": wb,
        "dominant": dom,
        "model_flops_ratio": (mf / flops) if flops else 0.0,
        "bound_s": max(t_compute, t_memory, t_coll),
    }


def load(art_dir: str) -> List[Dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(f) as fh:
            r = json.load(fh)
        if r.get("applicable") and "cost" in r:
            r["roofline"] = roofline_terms(r)
        recs.append(r)
    return recs


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--art", default="artifacts/dryrun")
    ap.add_argument("--mesh", default="pod")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args(argv)

    recs = load(args.art)
    # the roofline table is single-pod only (multipod artifacts skip the
    # depth-point correction; they exist to prove lower+compile)
    recs = [r for r in recs if r.get("mesh") == args.mesh]
    if not recs:
        print(f"# no dry-run artifacts under {args.art} — run "
              f"`python -m repro.launch.dryrun --all` first", file=sys.stderr)
        return
    print("name,us_per_call,t_compute_s,t_memory_s,t_collective_s,dominant,"
          "model_flops_ratio,temp_GiB")
    for r in recs:
        tag = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}/{r['step']}"
        if not r.get("applicable"):
            print(f"{tag},skip,,,,{r.get('skip_reason','')},,")
            continue
        if "roofline" not in r:
            print(f"{tag},ERROR,,,,{r.get('error','?')[:60]},,")
            continue
        rf = r["roofline"]
        temp = r.get("memory", {}).get("temp_size_in_bytes", 0) / 2**30
        print(f"{tag},{rf['bound_s']*1e6:.1f},{rf['t_compute']:.4e},"
              f"{rf['t_memory']:.4e},{rf['t_collective']:.4e},{rf['dominant']},"
              f"{rf['model_flops_ratio']:.3f},{temp:.2f}")


if __name__ == "__main__":
    main()
