"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.key(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-4, atol=2e-4)


# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("rows,d", [(8, 64), (64, 256), (32, 1024), (128, 80)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(rows, d, dtype):
    x = jax.random.normal(KEY, (rows, d), jnp.float32).astype(dtype)
    s = jax.random.normal(jax.random.fold_in(KEY, 1), (d,), jnp.float32)
    out = ops.rmsnorm(x, s)
    want = ref.ref_rmsnorm(x, s)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("S,H,KV,hd,bq,bk", [
    (128, 2, 1, 64, 64, 64),
    (256, 4, 2, 64, 128, 64),
    (128, 8, 8, 32, 32, 128),   # MHA
    (192, 3, 1, 128, 64, 64),   # non-power-of-two heads
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(S, H, KV, hd, bq, bk, dtype):
    B = 2
    q = jax.random.normal(KEY, (B, S, H, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, KV, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 3), (B, S, KV, hd), jnp.float32).astype(dtype)
    out = ops.flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk)
    rep = H // KV
    qh = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kh = jnp.repeat(k.transpose(0, 2, 1, 3), rep, 1).reshape(B * H, S, hd)
    vh = jnp.repeat(v.transpose(0, 2, 1, 3), rep, 1).reshape(B * H, S, hd)
    want = ref.ref_attention(qh, kh, vh, causal=True).reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window,softcap,causal", [
    (32, None, True), (None, 50.0, True), (64, 30.0, True), (None, None, False),
])
def test_flash_attention_features(window, softcap, causal):
    B, S, H, hd = 1, 128, 2, 64
    q = jax.random.normal(KEY, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 4), (B, S, H, hd), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(KEY, 5), (B, S, H, hd), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              softcap=softcap, block_q=32, block_k=32)
    qh = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kh = k.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    vh = v.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    want = ref.ref_attention(qh, kh, vh, causal=causal, window=window,
                             softcap=softcap).reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_flash_attention_matches_model_attention():
    """The kernel agrees with the model's _attend (same masks/scaling)."""
    from repro.configs import get_config
    from repro.models import attention as A
    cfg = get_config("gemma2-2b").reduced().with_(attn_chunk=0)
    B, S = 2, 64
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jax.random.normal(KEY, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 6), (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(KEY, 7), (B, S, KV, hd), jnp.float32)
    pos = jnp.arange(S, dtype=jnp.int32)
    want = A._attend(cfg, q, k, v, pos, pos, jnp.int32(8), causal=True)
    out = ops.flash_attention(q, k, v, causal=True, window=8,
                              softcap=cfg.attn_softcap, block_q=32, block_k=32)
    np.testing.assert_allclose(np.asarray(out.reshape(B, S, H * hd)),
                               np.asarray(want), rtol=2e-4, atol=2e-4)


# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("S,di,n,bd,bs", [
    (64, 64, 16, 32, 32), (128, 128, 8, 128, 64), (96, 32, 4, 16, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_selective_scan_sweep(S, di, n, bd, bs, dtype):
    B = 2
    u = (jax.random.normal(KEY, (B, S, di), jnp.float32) * 0.5).astype(dtype)
    dt = (jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 8), (B, S, di))) * 0.1).astype(dtype)
    Bm = jax.random.normal(jax.random.fold_in(KEY, 9), (B, S, n), jnp.float32).astype(dtype)
    Cm = jax.random.normal(jax.random.fold_in(KEY, 10), (B, S, n), jnp.float32).astype(dtype)
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(KEY, 11), (di, n)) * 0.2)
    Dp = jnp.ones((di,))
    out = ops.selective_scan(u, dt, Bm, Cm, A, Dp, block_d=bd, block_s=bs)
    want = ref.ref_selective_scan(u, dt, Bm, Cm, A, Dp)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               **(_tol(dtype) if dtype == jnp.bfloat16
                                  else dict(rtol=1e-4, atol=1e-4)))


def test_selective_scan_matches_model_ssm():
    """Kernel output matches models/ssm.py's associative-scan mixing core."""
    from repro.configs import get_config
    from repro.models import ssm as M
    cfg = get_config("falcon-mamba-7b").reduced()
    p = M.init_mamba(jax.random.key(1), cfg, jnp.float32)
    B, S = 2, 64
    u = jax.random.normal(KEY, (B, S, cfg.d_inner), jnp.float32) * 0.3
    u_c = jax.nn.silu(M._causal_conv(p, u, cfg.ssm_conv))
    dA, dBu, Cm = M._ssm_inputs(cfg, p, u_c)
    want = M.mamba_mix(cfg, p, u)
    # reconstruct kernel inputs (dt recomputed the same way)
    x_dbl = (u_c @ p["x_proj"]).astype(jnp.float32)
    dtr, n = cfg.dt_rank_actual, cfg.ssm_state
    dt_low, Bm, Cm2 = jnp.split(x_dbl, [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus(dt_low @ p["dt_w"].astype(jnp.float32) + p["dt_b"])
    A = -jnp.exp(p["A_log"])
    out = ops.selective_scan(u_c.astype(jnp.float32), dt, Bm, Cm2, A, p["D"],
                             block_d=64, block_s=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-4, atol=2e-4)


# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("n,block", [
    (4096, 1024), (8192, 4096), (2048, 2048),
    # tail blocks: n not a multiple of block (masked boundary path)
    (5000, 4096), (1000, 512), (37, 8), (3, 4096), (1, 4096),
])
def test_zo_kernels_sweep(n, block):
    ss = ops.zo_sumsq(n, 1234, offset=77, block=block)
    np.testing.assert_allclose(float(ss), float(ref.ref_zo_sumsq(n, 1234, 77)),
                               rtol=1e-5)
    x = jax.random.normal(KEY, (n,), jnp.float32)
    out = ops.zo_perturb(x, 55, 0.01, offset=3, block=block)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.ref_zo_perturb(x, 55, 0.01, 3)),
                               rtol=1e-6, atol=1e-6)
    salts = jnp.asarray([1, 2, 3, 4], jnp.uint32)
    coeffs = jnp.asarray([0.5, -1.0, 2.0, 0.1], jnp.float32)
    out = ops.zo_reconstruct(n, salts, coeffs, offset=9, block=block)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.ref_zo_reconstruct(n, salts, coeffs, 9)),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n,block", [(1000, 512), (2048, 2048)])
def test_zo_reconstruct_acc_dtype(n, block):
    """Per-worker bf16 accumulator rounding matches the oracle bit-for-bit
    (the rounding quantizes away the kernel/oracle fma-order freedom)."""
    salts = jnp.asarray([7, 11, 13, 17], jnp.uint32)
    coeffs = jnp.asarray([0.25, -0.75, 1.5, 0.3], jnp.float32)
    out = ops.zo_reconstruct(n, salts, coeffs, offset=0, block=block,
                             acc_dtype="bfloat16")
    want = ref.ref_zo_reconstruct(n, salts, coeffs, 0, acc_dtype="bfloat16")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


# --------------------------------------------------------------------------- #
# flat (packed multi-leaf) kernels: block metadata sweeps + the fused commit
# --------------------------------------------------------------------------- #
# All bit-level comparisons hold jit-ness constant (jitted kernel vs jitted
# oracle): XLA contracts a + s*b to fma under jit but not eagerly, so an
# eager oracle differs in the last ulp for structural — not numerical —
# reasons.

FLAT_LAYOUTS = [
    ([1000, 261], 256),   # tail blocks on both leaves
    ([37, 3, 1], 8),      # tiny leaves incl. a scalar-sized one
    ([129], 64),          # single leaf, odd tail
]


def _flat_meta(sizes, block, base_salt=100):
    """Per-block (leaf salt, leaf-local counter start, valid lanes)."""
    salts, ctrs, nvalid = [], [], []
    for li, n in enumerate(sizes):
        for b in range(max(1, -(-n // block))):
            salts.append(base_salt + li)
            ctrs.append(b * block)
            nvalid.append(min(block, n - b * block))
    return (jnp.asarray(salts, jnp.uint32), jnp.asarray(ctrs, jnp.uint32),
            jnp.asarray(nvalid, jnp.int32))


def _packed(sizes, block, key=KEY):
    """Block-aligned packed buffer: leaf data, zero padding lanes."""
    parts = []
    for li, n in enumerate(sizes):
        nb = max(1, -(-n // block))
        x = jax.random.normal(jax.random.fold_in(key, li), (n,), jnp.float32)
        parts.append(jnp.pad(x, (0, nb * block - n)))
    return jnp.concatenate(parts)


@pytest.mark.parametrize("sizes,block", FLAT_LAYOUTS)
def test_zo_perturb_flat_sweep(sizes, block):
    salts, ctrs, nvalid = _flat_meta(sizes, block)
    x = _packed(sizes, block)
    scale = jnp.float32(3e-3)
    out = ops.zo_perturb_flat(x, salts, ctrs, nvalid, scale, block=block)

    @jax.jit
    def oracle(x, scale):
        outs = []
        for b in range(int(salts.shape[0])):
            g = ref._ref_flat_gauss(salts[b], ctrs[b], nvalid[b], block)
            xb = x[b * block:(b + 1) * block]
            valid = jnp.arange(block) < nvalid[b]
            outs.append(jnp.where(valid, xb + scale * g, xb))
        return jnp.concatenate(outs)

    np.testing.assert_array_equal(np.asarray(out), np.asarray(oracle(x, scale)))


@pytest.mark.parametrize("sizes,block", FLAT_LAYOUTS)
@pytest.mark.parametrize("acc_dtype", ["float32", "bfloat16"])
def test_zo_reconstruct_flat_sweep(sizes, block, acc_dtype):
    m = 4
    salts1, ctrs, nvalid = _flat_meta(sizes, block)
    msalts = jnp.stack([salts1 + jnp.uint32(w * 1009) for w in range(m)], axis=1)
    coeffs = jnp.asarray([0.5, -1.0, 2.0, 0.1], jnp.float32)
    out = ops.zo_reconstruct_flat(msalts, coeffs, ctrs, nvalid, block=block,
                                  acc_dtype=acc_dtype)

    @jax.jit
    def oracle(coeffs):
        adt = jnp.dtype(acc_dtype)
        outs = []
        for b in range(int(msalts.shape[0])):
            acc = jnp.zeros((block,), jnp.float32)
            for w in range(m):
                g = ref._ref_flat_gauss(msalts[b, w], ctrs[b], nvalid[b], block)
                acc = (acc + coeffs[w] * g).astype(adt).astype(jnp.float32)
            outs.append(acc)
        return jnp.concatenate(outs)

    np.testing.assert_array_equal(np.asarray(out), np.asarray(oracle(coeffs)))


@pytest.mark.parametrize("sizes,block", FLAT_LAYOUTS)
def test_zo_perturb_sumsq_matches_oracle(sizes, block):
    """One launch = perturb AND the tree-wide sumsq (blockwise-sequential
    accumulation, mirrored exactly by the oracle)."""
    salts, ctrs, nvalid = _flat_meta(sizes, block)
    x = _packed(sizes, block)
    out, ss = ops.zo_perturb_sumsq(x, salts, ctrs, nvalid, 1e-3, block=block)
    oracle = jax.jit(lambda x: ref.ref_zo_perturb_sumsq(
        x, salts, ctrs, nvalid, 1e-3, block=block))
    want, wss = oracle(x)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(ss).reshape(()), np.asarray(wss))
    # padding lanes never contribute to the norm
    g = (np.asarray(want) - np.asarray(x))
    valid_total = sum(sizes)
    assert np.count_nonzero(g) <= valid_total


@pytest.mark.parametrize("momentum", [0.0, 0.9])
def test_zo_reconstruct_update_matches_ref(momentum):
    """Fused commit kernel vs its jnp oracle, incl. the bf16-leaf rounding
    path (bf16_mask marks the second leaf's blocks)."""
    sizes, block = [1000, 261], 256
    salts1, ctrs, nvalid = _flat_meta(sizes, block)
    m = 4
    msalts = jnp.stack([salts1 + jnp.uint32(w * 613) for w in range(m)], axis=1)
    # leaf 0 (4 blocks of 256) fp32; leaf 1 (2 blocks) commits through bf16
    bf16 = jnp.asarray([0, 0, 0, 0, 1, 1], jnp.int32)
    coeffs = jnp.asarray([0.25, -0.75, 1.5, 0.3], jnp.float32)
    p = _packed(sizes, block)
    mom = None if momentum == 0.0 else jnp.zeros_like(p) + 0.1
    lr = 0.05
    got_p, got_m = ops.zo_reconstruct_update(
        p.copy(), None if mom is None else mom.copy(), msalts, ctrs, nvalid,
        bf16, coeffs, lr, momentum=momentum, block=block)
    oracle = jax.jit(lambda p, mom, c: ref.ref_zo_reconstruct_update(
        p, mom, msalts, ctrs, nvalid, bf16, c, lr, momentum=momentum,
        block=block))
    want_p, want_m = oracle(p, mom, coeffs)
    np.testing.assert_array_equal(np.asarray(got_p), np.asarray(want_p))
    if momentum:
        np.testing.assert_array_equal(np.asarray(got_m), np.asarray(want_m))
    else:
        assert got_m is None


@pytest.mark.parametrize("m", [1, 4])
@pytest.mark.parametrize("acc_dtype", ["float32", "bfloat16"])
def test_zo_reconstruct_update_matches_opt_apply(m, acc_dtype):
    """ISSUE 10 satellite pin: the fused commit kernel equals the unfused
    composition ``apply_deltas ∘ sgd.update ∘ zo_reconstruct_flat`` across
    momentum steps, m, accumulator dtypes, and uneven tail blocks.

    The kernel commits ``p + (-lr)*v`` with the same multiply-add structure
    the composition lowers to, so with both sides jitted the trajectories
    are bit-identical (the ISSUE floor is ulp-bounded fp32 / bit-identical
    bf16-acc; the structural match gives bitwise in both)."""
    from repro.opt.optimizers import apply_deltas, const_schedule, sgd

    sizes, block = [1000, 261], 256
    salts1, ctrs, nvalid = _flat_meta(sizes, block)
    msalts = jnp.stack([salts1 + jnp.uint32(w * 271) for w in range(m)], axis=1)
    bf16 = jnp.zeros((salts1.shape[0],), jnp.int32)
    lr, momentum = 0.05, 0.9
    opt = sgd(const_schedule(lr), momentum)

    @jax.jit
    def step_unfused(p, state, coeffs, t):
        g = ops.zo_reconstruct_flat(msalts, coeffs, ctrs, nvalid, block=block,
                                    acc_dtype=acc_dtype)
        deltas, state = opt.update(g, state, p, t)
        return apply_deltas(p, deltas), state

    p_ref = _packed(sizes, block)
    state = opt.init(p_ref)
    p_k, mom_k = p_ref, jnp.zeros_like(p_ref)
    for t in range(3):
        coeffs = jnp.linspace(-1.0, 1.0, m + 1)[1:] * jnp.float32(t + 1)
        p_ref, state = step_unfused(p_ref, state, coeffs, t)
        p_k, mom_k = ops.zo_reconstruct_update(
            p_k, mom_k, msalts, ctrs, nvalid, bf16, coeffs, lr,
            momentum=momentum, block=block, acc_dtype=acc_dtype)
    np.testing.assert_array_equal(np.asarray(p_k), np.asarray(p_ref))
    np.testing.assert_array_equal(np.asarray(mom_k), np.asarray(state))


def test_zo_reconstruct_update_donates_eagerly():
    """The commit op consumes its packed buffers in place (donation) — the
    flat engine's fused step path relies on never re-reading them."""
    sizes, block = [129], 64
    salts1, ctrs, nvalid = _flat_meta(sizes, block)
    msalts = salts1[:, None]
    bf16 = jnp.zeros_like(nvalid)
    p = _packed(sizes, block)
    out, _ = ops.zo_reconstruct_update(
        p, None, msalts, ctrs, nvalid, bf16,
        jnp.ones((1,), jnp.float32), 0.1, block=block)
    assert p.is_deleted()
    assert not out.is_deleted()


def test_zo_kernel_matches_optimizer_directions():
    """The Pallas hash is bit-identical to the optimizer's direction gen:
    perturbing leaf-by-leaf with the kernel == directions.sphere + axpy."""
    from repro.core import directions as D
    params = {"w": jax.random.normal(KEY, (4096,)), "b": jax.random.normal(KEY, (2048,))}
    seed, t, worker, mu = 3, jnp.int32(5), jnp.uint32(2), 1e-2
    v = D.sphere_direction(params, seed, t, worker)
    want = D.tree_axpy(jnp.float32(mu), v, params)
    # kernel path: per-leaf salts, global norm via zo_sumsq, then zo_perturb
    leaves, treedef = jax.tree.flatten(params)
    salts = [D.fold(seed, t, worker, i) for i in range(len(leaves))]
    ssq = sum(float(ops.zo_sumsq(x.size, s, 0, block=2048))
              for x, s in zip(leaves, salts))
    inv = 1.0 / np.sqrt(ssq)
    got = [ops.zo_perturb(x, s, mu * inv, 0, block=2048)
           for x, s in zip(leaves, salts)]
    for g, w in zip(got, jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-5, atol=1e-6)
