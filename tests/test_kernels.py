"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.key(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-4, atol=2e-4)


# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("rows,d", [(8, 64), (64, 256), (32, 1024), (128, 80)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(rows, d, dtype):
    x = jax.random.normal(KEY, (rows, d), jnp.float32).astype(dtype)
    s = jax.random.normal(jax.random.fold_in(KEY, 1), (d,), jnp.float32)
    out = ops.rmsnorm(x, s)
    want = ref.ref_rmsnorm(x, s)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("S,H,KV,hd,bq,bk", [
    (128, 2, 1, 64, 64, 64),
    (256, 4, 2, 64, 128, 64),
    (128, 8, 8, 32, 32, 128),   # MHA
    (192, 3, 1, 128, 64, 64),   # non-power-of-two heads
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(S, H, KV, hd, bq, bk, dtype):
    B = 2
    q = jax.random.normal(KEY, (B, S, H, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, KV, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 3), (B, S, KV, hd), jnp.float32).astype(dtype)
    out = ops.flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk)
    rep = H // KV
    qh = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kh = jnp.repeat(k.transpose(0, 2, 1, 3), rep, 1).reshape(B * H, S, hd)
    vh = jnp.repeat(v.transpose(0, 2, 1, 3), rep, 1).reshape(B * H, S, hd)
    want = ref.ref_attention(qh, kh, vh, causal=True).reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window,softcap,causal", [
    (32, None, True), (None, 50.0, True), (64, 30.0, True), (None, None, False),
])
def test_flash_attention_features(window, softcap, causal):
    B, S, H, hd = 1, 128, 2, 64
    q = jax.random.normal(KEY, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 4), (B, S, H, hd), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(KEY, 5), (B, S, H, hd), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              softcap=softcap, block_q=32, block_k=32)
    qh = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kh = k.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    vh = v.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    want = ref.ref_attention(qh, kh, vh, causal=causal, window=window,
                             softcap=softcap).reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_flash_attention_matches_model_attention():
    """The kernel agrees with the model's _attend (same masks/scaling)."""
    from repro.configs import get_config
    from repro.models import attention as A
    cfg = get_config("gemma2-2b").reduced().with_(attn_chunk=0)
    B, S = 2, 64
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jax.random.normal(KEY, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 6), (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(KEY, 7), (B, S, KV, hd), jnp.float32)
    pos = jnp.arange(S, dtype=jnp.int32)
    want = A._attend(cfg, q, k, v, pos, pos, jnp.int32(8), causal=True)
    out = ops.flash_attention(q, k, v, causal=True, window=8,
                              softcap=cfg.attn_softcap, block_q=32, block_k=32)
    np.testing.assert_allclose(np.asarray(out.reshape(B, S, H * hd)),
                               np.asarray(want), rtol=2e-4, atol=2e-4)


# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("S,di,n,bd,bs", [
    (64, 64, 16, 32, 32), (128, 128, 8, 128, 64), (96, 32, 4, 16, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_selective_scan_sweep(S, di, n, bd, bs, dtype):
    B = 2
    u = (jax.random.normal(KEY, (B, S, di), jnp.float32) * 0.5).astype(dtype)
    dt = (jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 8), (B, S, di))) * 0.1).astype(dtype)
    Bm = jax.random.normal(jax.random.fold_in(KEY, 9), (B, S, n), jnp.float32).astype(dtype)
    Cm = jax.random.normal(jax.random.fold_in(KEY, 10), (B, S, n), jnp.float32).astype(dtype)
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(KEY, 11), (di, n)) * 0.2)
    Dp = jnp.ones((di,))
    out = ops.selective_scan(u, dt, Bm, Cm, A, Dp, block_d=bd, block_s=bs)
    want = ref.ref_selective_scan(u, dt, Bm, Cm, A, Dp)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               **(_tol(dtype) if dtype == jnp.bfloat16
                                  else dict(rtol=1e-4, atol=1e-4)))


def test_selective_scan_matches_model_ssm():
    """Kernel output matches models/ssm.py's associative-scan mixing core."""
    from repro.configs import get_config
    from repro.models import ssm as M
    cfg = get_config("falcon-mamba-7b").reduced()
    p = M.init_mamba(jax.random.key(1), cfg, jnp.float32)
    B, S = 2, 64
    u = jax.random.normal(KEY, (B, S, cfg.d_inner), jnp.float32) * 0.3
    u_c = jax.nn.silu(M._causal_conv(p, u, cfg.ssm_conv))
    dA, dBu, Cm = M._ssm_inputs(cfg, p, u_c)
    want = M.mamba_mix(cfg, p, u)
    # reconstruct kernel inputs (dt recomputed the same way)
    x_dbl = (u_c @ p["x_proj"]).astype(jnp.float32)
    dtr, n = cfg.dt_rank_actual, cfg.ssm_state
    dt_low, Bm, Cm2 = jnp.split(x_dbl, [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus(dt_low @ p["dt_w"].astype(jnp.float32) + p["dt_b"])
    A = -jnp.exp(p["A_log"])
    out = ops.selective_scan(u_c.astype(jnp.float32), dt, Bm, Cm2, A, p["D"],
                             block_d=64, block_s=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-4, atol=2e-4)


# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("n,block", [
    (4096, 1024), (8192, 4096), (2048, 2048),
    # tail blocks: n not a multiple of block (masked boundary path)
    (5000, 4096), (1000, 512), (37, 8), (3, 4096), (1, 4096),
])
def test_zo_kernels_sweep(n, block):
    ss = ops.zo_sumsq(n, 1234, offset=77, block=block)
    np.testing.assert_allclose(float(ss), float(ref.ref_zo_sumsq(n, 1234, 77)),
                               rtol=1e-5)
    x = jax.random.normal(KEY, (n,), jnp.float32)
    out = ops.zo_perturb(x, 55, 0.01, offset=3, block=block)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.ref_zo_perturb(x, 55, 0.01, 3)),
                               rtol=1e-6, atol=1e-6)
    salts = jnp.asarray([1, 2, 3, 4], jnp.uint32)
    coeffs = jnp.asarray([0.5, -1.0, 2.0, 0.1], jnp.float32)
    out = ops.zo_reconstruct(n, salts, coeffs, offset=9, block=block)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.ref_zo_reconstruct(n, salts, coeffs, 9)),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n,block", [(1000, 512), (2048, 2048)])
def test_zo_reconstruct_acc_dtype(n, block):
    """Per-worker bf16 accumulator rounding matches the oracle bit-for-bit
    (the rounding quantizes away the kernel/oracle fma-order freedom)."""
    salts = jnp.asarray([7, 11, 13, 17], jnp.uint32)
    coeffs = jnp.asarray([0.25, -0.75, 1.5, 0.3], jnp.float32)
    out = ops.zo_reconstruct(n, salts, coeffs, offset=0, block=block,
                             acc_dtype="bfloat16")
    want = ref.ref_zo_reconstruct(n, salts, coeffs, 0, acc_dtype="bfloat16")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


def test_zo_kernel_matches_optimizer_directions():
    """The Pallas hash is bit-identical to the optimizer's direction gen:
    perturbing leaf-by-leaf with the kernel == directions.sphere + axpy."""
    from repro.core import directions as D
    params = {"w": jax.random.normal(KEY, (4096,)), "b": jax.random.normal(KEY, (2048,))}
    seed, t, worker, mu = 3, jnp.int32(5), jnp.uint32(2), 1e-2
    v = D.sphere_direction(params, seed, t, worker)
    want = D.tree_axpy(jnp.float32(mu), v, params)
    # kernel path: per-leaf salts, global norm via zo_sumsq, then zo_perturb
    leaves, treedef = jax.tree.flatten(params)
    salts = [D.fold(seed, t, worker, i) for i in range(len(leaves))]
    ssq = sum(float(ops.zo_sumsq(x.size, s, 0, block=2048))
              for x, s in zip(leaves, salts))
    inv = 1.0 / np.sqrt(ssq)
    got = [ops.zo_perturb(x, s, mu * inv, 0, block=2048)
           for x, s in zip(leaves, salts)]
    for g, w in zip(got, jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-5, atol=1e-6)
