"""CommLedger semantics + codec round-trips + measured bytes on a 1x1 mesh.

The 4-worker measured-vs-analytic check runs in a subprocess with its own
XLA_FLAGS (tests/helpers/ledger_check.py via test_distributed.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.distributed import make_distributed_ho_sgd
from repro.core.ho_sgd import HOSGDConfig
from repro.dist import CommLedger, collectives as coll
from repro.dist.compress import compress_tree, get_compressor, qsgd, signsgd, topk
from repro.launch.mesh import make_test_mesh
from repro.opt.optimizers import const_schedule, sgd


# --------------------------------------------------------------------------- #
# ledger mechanics
# --------------------------------------------------------------------------- #
def test_ledger_books_per_step_and_excludes_diagnostics():
    ledger = CommLedger()

    def fake_step(x):
        coll.note("all_gather", jnp.zeros((4,), jnp.float32), tag="coeffs")
        coll.note("pmean", jnp.zeros((), jnp.float32), tag="loss",
                  payload=False)
        return x

    step = ledger.wrap("zo", fake_step)
    for _ in range(3):
        step(1.0)
    assert ledger.bytes_per_step("zo") == 16                 # 4 fp32, not loss
    assert ledger.bytes_per_step("zo", payload_only=False) == 20
    assert ledger.steps["zo"] == 3
    assert ledger.total_bytes() == 48
    assert ledger.by_kind("zo") == {"all_gather:coeffs": 16, "pmean:loss": 4}
    ledger.reset()
    assert ledger.total_bytes() == 0
    assert ledger.bytes_per_step("zo") == 16                 # program survives


def test_ledger_wrap_survives_jit_caching():
    ledger = CommLedger()

    @jax.jit
    def traced(x):
        coll.note("all_reduce", x, tag="grads")
        return x + 1

    step = ledger.wrap("fo", traced)
    x = jnp.zeros((8,), jnp.float32)
    step(x)
    step(x)   # cache hit: no re-record, but the step still counts
    assert ledger.bytes_per_step("fo") == 32
    assert ledger.steps["fo"] == 2
    assert ledger.total_bytes() == 64


def test_collectives_record_nothing_outside_a_wrap():
    out = coll.note("all_reduce", jnp.zeros((4,), jnp.float32))
    assert out.shape == (4,)   # identity, no error, no global state


# --------------------------------------------------------------------------- #
# codecs
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("comp", [qsgd(4), qsgd(16), signsgd(), topk(0.1)])
def test_codec_roundtrip_shape_and_wire_budget(comp):
    g = jnp.asarray(np.random.default_rng(0).normal(size=(4096,)), jnp.float32)
    dec = comp.decode(comp.encode(g, jax.random.key(0)))
    assert dec.shape == g.shape and dec.dtype == jnp.float32
    assert bool(jnp.all(jnp.isfinite(dec)))
    assert comp.nbytes(g.size) < 4 * g.size   # beats the dense wire format


def test_qsgd_quantization_is_unbiased():
    g = jnp.asarray(np.random.default_rng(1).normal(size=(512,)), jnp.float32)
    comp = qsgd(4)
    dec = jnp.stack([
        comp.decode(comp.encode(g, jax.random.key(i))) for i in range(64)
    ])
    err = jnp.mean(dec, 0) - g
    # stochastic rounding: the mean over keys converges on g
    assert float(jnp.max(jnp.abs(err))) < 0.2 * float(jnp.linalg.norm(g)) / 4


def test_signsgd_keeps_signs_topk_keeps_largest():
    g = jnp.asarray([3.0, -2.0, 0.5, -0.1])
    s_dec = signsgd().decode(signsgd().encode(g, jax.random.key(0)))
    assert bool(jnp.all(jnp.sign(s_dec) == jnp.sign(g)))
    t = topk(k=2)
    t_dec = t.decode(t.encode(g, jax.random.key(0)))
    np.testing.assert_allclose(np.asarray(t_dec), [3.0, -2.0, 0.0, 0.0])


def test_compress_tree_preserves_structure_and_books_bytes():
    tree = {"a": jnp.ones((64, 8), jnp.float32), "b": jnp.ones((100,), jnp.float32)}
    out, nbytes = compress_tree(signsgd(), tree, jax.random.key(0))
    assert jax.tree.structure(out) == jax.tree.structure(tree)
    assert out["a"].shape == (64, 8)
    assert nbytes == (4 + 512 // 8) + (4 + (100 + 7) // 8)


def test_get_compressor_registry():
    assert get_compressor("none") is None and get_compressor(None) is None
    assert get_compressor("qsgd").name == "qsgd4"
    with pytest.raises(ValueError):
        get_compressor("zip")


# --------------------------------------------------------------------------- #
# measured bytes through the real distributed steps (degenerate 1x1 mesh)
# --------------------------------------------------------------------------- #
def quad_loss(params, batch):
    return 0.5 * jnp.mean(jnp.sum((params["x"] - batch["t"]) ** 2, -1))


def _run_steps(compressor=None):
    mesh = make_test_mesh(data=1, model=1)
    d = 64
    ho = HOSGDConfig(tau=4, mu=1e-3, m=1, lr=0.05, zo_lr=0.05 / d)
    opt = sgd(const_schedule(ho.lr))
    fo, zo = make_distributed_ho_sgd(quad_loss, mesh, ho, opt,
                                     compressor=compressor)
    ledger = CommLedger()
    fo_j = ledger.wrap("fo", jax.jit(fo))
    zo_j = ledger.wrap("zo", jax.jit(zo))
    params = {"x": jnp.zeros((d,), jnp.float32)}
    state = opt.init(params)
    batch = {"t": jnp.ones((4, d), jnp.float32)}
    params, state, _ = fo_j(jnp.int32(0), params, state, batch)
    params, state, _ = zo_j(jnp.int32(1), params, state, batch)
    return ledger, d


def test_measured_bytes_match_table1_on_degenerate_mesh():
    ledger, d = _run_steps()
    assert ledger.bytes_per_step("fo") == 4 * d     # the gradient all-reduce
    assert ledger.bytes_per_step("zo") == 4 * 1     # m scalars, m=1 — not d!


def test_fsdp_zo_single_books_its_one_scalar():
    """The fsdp (m=1) ZO path books 4 bytes — measured, not a silent 0."""
    from repro.core.distributed import make_zo_step
    mesh = make_test_mesh(data=1, model=1)
    d = 64
    ho = HOSGDConfig(tau=4, mu=1e-3, m=1, lr=0.05, zo_lr=0.05 / d)
    opt = sgd(const_schedule(ho.lr))
    zo = make_zo_step(quad_loss, mesh, ho, opt, fsdp=True)
    ledger = CommLedger()
    zo_j = ledger.wrap("zo", jax.jit(zo))
    params = {"x": jnp.zeros((d,), jnp.float32)}
    zo_j(jnp.int32(1), params, opt.init(params),
         {"t": jnp.ones((4, d), jnp.float32)})
    assert ledger.bytes_per_step("zo") == 4


def test_qsgd_fo_step_records_fewer_bytes_than_dense():
    dense, d = _run_steps()
    compressed, _ = _run_steps(get_compressor("qsgd"))
    assert compressed.bytes_per_step("fo") < dense.bytes_per_step("fo") == 4 * d
    # zo traffic is untouched by the codec
    assert compressed.bytes_per_step("zo") == dense.bytes_per_step("zo")


# --------------------------------------------------------------------------- #
# faithful per-worker QSGD (ISSUE 5): each worker encodes its own shard
# gradient and the reducer decodes — wire bytes = nbytes × active workers
# --------------------------------------------------------------------------- #
def _fo_bytes(compressor, m, compress_mode):
    from repro.core.distributed import make_fo_step
    mesh = make_test_mesh(data=1, model=1)
    d = 64
    opt = sgd(const_schedule(0.05))
    fo = make_fo_step(quad_loss, mesh, opt, compressor=compressor,
                      compress_mode=compress_mode, m=m)
    ledger = CommLedger()
    fo_j = ledger.wrap("fo", jax.jit(fo))
    params = {"x": jnp.zeros((d,), jnp.float32)}
    fo_j(jnp.int32(0), params, opt.init(params),
         {"t": jnp.ones((2 * m, d), jnp.float32)})
    return ledger.bytes_per_step("fo"), d


@pytest.mark.parametrize("m", [1, 4])
def test_per_worker_fo_encode_books_nbytes_times_workers(m):
    codec = qsgd(4)
    pw, d = _fo_bytes(codec, m, "per_worker")
    assert pw == codec.nbytes(d) * m
    legacy, _ = _fo_bytes(codec, m, "legacy")
    assert legacy == codec.nbytes(d)
    if m == 1:       # the degenerate mesh: the two protocols coincide
        assert pw == legacy


def test_bucketed_fo_lowering_books_identical_bytes():
    """The overlap contract (ISSUE 7): bucketing the FO all-reduce changes
    WHEN bytes move (chunk k's collective overlaps chunk k+1's compute),
    never HOW MANY — the ledger must book bit-identical bytes for every
    bucket count, dense and compressed alike."""
    from repro.core.distributed import make_fo_step
    mesh = make_test_mesh(data=1, model=1)
    d = 64
    opt = sgd(const_schedule(0.05))

    def fo_bytes(buckets, compressor=None):
        fo = make_fo_step(quad_loss, mesh, opt, compressor=compressor, m=1,
                          buckets=buckets)
        ledger = CommLedger()
        fo_j = ledger.wrap("fo", jax.jit(fo))
        params = {"x": jnp.zeros((d,), jnp.float32)}
        fo_j(jnp.int32(0), params, opt.init(params),
             {"t": jnp.ones((2, d), jnp.float32)})
        return ledger.bytes_per_step("fo")

    assert [fo_bytes(b) for b in (1, 2, 8)] == [4 * d] * 3
    codec = qsgd(4)
    assert ({fo_bytes(b, codec) for b in (1, 2, 8)}
            == {codec.nbytes(d)})


def test_round_executor_books_nbytes_times_active_workers():
    """The round IR's wire model through a ledger-wrapped executor: a
    per-worker-encoded all_reduce over the LIVE membership books
    dist.compress.nbytes × active workers (legacy: one payload)."""
    from repro.core.baselines import qsgd_program
    from repro.core.rounds import RoundExecutor

    d, m, s = 64, 4, 8
    params = {"x": jnp.zeros((d,), jnp.float32)}
    batch = {"t": jnp.ones((2 * m, d), jnp.float32)}
    for mode, active, mult in [("per_worker", None, m),
                               ("per_worker", [0, 2, 3], 3),
                               ("legacy", None, 1)]:
        ex = RoundExecutor(qsgd_program(quad_loss, m, s, 0.1,
                                        compress_mode=mode))
        ledger = CommLedger()
        run = ledger.wrap("q", lambda *a, **k: ex.run(*a, **k))
        _, _, met = run(0, params, {}, batch, workers=active)
        expect = qsgd(s).nbytes(d) * mult
        assert met["comm_bytes"] == expect == ledger.bytes_per_step("q")
