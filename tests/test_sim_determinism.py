"""Property suite for the extended determinism contract (README §repro.sim):
same ``ClusterSpec`` (seed included) + same method and data ⇒ bit-identical
event trace — ACROSS every scenario class the simulator supports, including
the ones where nondeterminism is easiest to smuggle in (unbarriered async
rounds, elastic leave/rejoin through real checkpoint round-trips, and
hierarchical multi-pod collectives).  Specs are themselves randomized from a
per-case seed, so each case pins the contract on a different corner of the
spec space rather than one hand-picked configuration.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.sim import (
    ClusterSpec,
    Topology,
    compute_model_for,
    make_sim_methods,
    simulate,
)

QUAD_D, QUAD_M = 48, 4
N_ITERS, TAU = 10, 4


def quad_loss(params, batch):
    return 0.5 * jnp.mean(jnp.sum((params["x"] - batch["t"]) ** 2, -1))


def quad_problem():
    params = {"x": jnp.zeros((QUAD_D,), jnp.float32)}
    batch = {"t": jnp.ones((2 * QUAD_M, QUAD_D), jnp.float32)}
    return params, batch


def run(spec, which="ho_sgd", replay="per_worker", overlap=1):
    params, batch = quad_problem()

    def batches():
        while True:
            yield batch

    sm = make_sim_methods(quad_loss, params, spec, tau=TAU, lr=0.1,
                          zo_lr=0.05, which=[which],
                          overlap_buckets=overlap)[which]
    compute = compute_model_for(params, spec, 2)
    return simulate(sm, params, batches(), spec, N_ITERS, compute=compute,
                    replay=replay)


def random_base_spec(case_seed: int) -> ClusterSpec:
    """A randomized-but-seeded spec: jitter is always on (so distinct spec
    seeds provably diverge) and the link is slow enough that collectives
    dominate (the paper's regime)."""
    r = np.random.default_rng(case_seed)
    return ClusterSpec(
        m=QUAD_M,
        flops_per_sec=float(r.uniform(5e8, 2e9)),
        alpha=float(r.uniform(1e-6, 1e-4)),
        bandwidth=float(r.uniform(5e5, 5e6)),
        straggler_prob=float(r.uniform(0.0, 0.5)),
        straggler_slowdown=float(r.uniform(2.0, 6.0)),
        jitter_sigma=float(r.uniform(0.05, 0.3)),
        seed=int(r.integers(1, 2**31)),
    )


def scenario(base: ClusterSpec, name: str) -> ClusterSpec:
    if name == "sync":
        return base
    if name == "async2":
        return base.with_(max_staleness=2)
    if name == "elastic":
        # iteration duration here is ~1e-4..1e-3 sim seconds, so this rate
        # and sub-iteration mean downtime guarantee leave/rejoin cycles
        # inside N_ITERS committed rounds
        return base.with_(elastic=True, fail_rate=5000.0, downtime=5e-5,
                          restart_time=1e-5)
    if name == "2pod_ring":
        return base.with_(collective="ring",
                          topology=Topology(pods=2, inter_alpha=1e-4,
                                            inter_bandwidth=base.bandwidth / 4))
    raise ValueError(name)


SCENARIOS = ["sync", "async2", "elastic", "2pod_ring"]


@pytest.mark.parametrize("overlap", [1, 4])
@pytest.mark.parametrize("replay", ["per_worker", "monolithic"])
@pytest.mark.parametrize("case_seed", [11, 29])
@pytest.mark.parametrize("name", SCENARIOS)
def test_same_spec_bit_identical_trace(case_seed, name, replay, overlap):
    """Overlapped rounds (bucketed collectives) and shared-link contention
    (on by default; exercised by async2) must preserve the bit-identical
    replay contract, not just the strict compute-then-communicate path."""
    spec = scenario(random_base_spec(case_seed), name)
    r1 = run(spec, replay=replay, overlap=overlap)
    r2 = run(spec, replay=replay, overlap=overlap)
    assert r1.trace == r2.trace           # bit-identical, floats included
    assert r1.times == r2.times
    assert r1.losses == r2.losses
    assert r1.active_counts == r2.active_counts
    assert r1.failures == r2.failures and r1.rejoins == r2.rejoins
    for a, b in zip(jax.tree.leaves(r1.params), jax.tree.leaves(r2.params)):
        assert jnp.array_equal(a, b)


@pytest.mark.parametrize("name", SCENARIOS)
def test_distinct_seeds_diverge(name):
    base = random_base_spec(11)
    spec_a = scenario(base, name)
    spec_b = scenario(base.with_(seed=base.seed + 1), name)
    assert run(spec_a).trace != run(spec_b).trace


def test_elastic_scenario_exercises_leave_and_rejoin():
    """The elastic scenario class must actually shrink and regrow W —
    otherwise the property above pins nothing new."""
    res = run(scenario(random_base_spec(11), "elastic"))
    kinds = [k for _, k, _ in res.trace]
    assert res.failures > 0 and "leave" in kinds
    assert res.rejoins > 0 and "rejoin" in kinds and "restore" in kinds
    assert min(res.active_counts) < QUAD_M


def test_monolithic_elastic_failure_never_skips_a_batch():
    """The MONOLITHIC replay's contract: membership changes the PRICE of an
    iteration, never its math — with a batch stream that differs every
    iteration, an elastic run's committed params must still match the
    never-failed run bit-for-bit (a failure that dropped the in-flight
    batch would diverge immediately).  The default per-worker replay
    intentionally breaks this equality — only the live workers' shards
    enter the round — which is pinned by tests/test_replay_fidelity.py."""
    params, _ = quad_problem()

    def batches():
        i = 0
        while True:
            yield {"t": jnp.full((2 * QUAD_M, QUAD_D), 1.0 + 0.1 * (i % 7),
                                 jnp.float32)}
            i += 1

    def go(spec):
        sm = make_sim_methods(quad_loss, params, spec, tau=TAU, lr=0.1,
                              zo_lr=0.05, which=["ho_sgd"])["ho_sgd"]
        return simulate(sm, params, batches(), spec, N_ITERS,
                        compute=compute_model_for(params, spec, 2),
                        replay="monolithic")

    elastic = scenario(random_base_spec(11), "elastic")
    res = go(elastic)
    assert res.failures > 0
    ref = go(elastic.with_(fail_rate=0.0, elastic=False))
    assert res.losses == ref.losses
    for a, b in zip(jax.tree.leaves(res.params), jax.tree.leaves(ref.params)):
        assert jnp.array_equal(a, b)


def test_async_scenario_commits_unbarriered_rounds():
    res = run(scenario(random_base_spec(11), "async2"))
    kinds = [k for _, k, _ in res.trace]
    assert "async_exchange" in kinds        # ZO rounds ran unbarriered
    assert "all_reduce" in kinds            # FO syncs stayed barriered


def test_async_staleness_never_exceeds_bound():
    """No worker starts round r before round r-1-s has committed: with
    s = max_staleness, every compute start in the trace must be >= the
    commit time of the round s+1 back."""
    s = 2
    spec = random_base_spec(29).with_(max_staleness=s, straggler_prob=0.6)
    res = run(spec)
    commits = [t for t, k, _ in res.trace
               if k in ("all_reduce", "async_exchange", "barrier")]
    # reconstruct per-round compute starts from the trace: compute events
    # between commit r-1 and commit r belong to round r
    round_idx, starts = 0, {}
    for t, k, w in res.trace:
        if k == "compute":
            starts.setdefault(round_idx, []).append(t)
        elif k in ("all_reduce", "async_exchange", "barrier"):
            round_idx += 1
    for r, ts in starts.items():
        if r - 1 - s >= 0:
            gate = commits[r - 1 - s]
            # completion >= start >= gate (completion is what the trace has)
            assert all(t >= gate - 1e-12 for t in ts), (r, ts, gate)
