"""Round-program IR equivalence suite.

Two claims (ISSUE 5 acceptance):

1. The mesh lowerings (``core.distributed.make_fo_step`` / ``make_zo_step``)
   of the round IR are BIT-IDENTICAL to the pre-IR (PR-2) monolithic step
   functions on the synchronous full-membership path.  The PR-2 programs are
   preserved verbatim below as references — same expressions, same program
   structure, so fp32 bitwise equality is required (no FMA allowance needed:
   identical HLO; the documented FMA/ulp bounds of the engine suite apply
   only where program STRUCTURE differs, claim 2).
2. The reference executor (``rounds.RoundExecutor`` — what the simulator's
   per-worker replay runs when membership/staleness force it off the
   monolithic program) computes the same math as the single-host reference
   ``make_ho_sgd``, within the engine suite's documented cross-program
   tolerances (vmapped-vs-unrolled coefficient evals, fp32 chained
   accumulation).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import rounds as R
from repro.core.distributed import make_fo_step, make_zo_step
from repro.core.engine import make_engine
from repro.core.ho_sgd import HOSGDConfig, make_ho_sgd
from repro.launch.mesh import make_test_mesh
from repro.opt.optimizers import apply_deltas, const_schedule, sgd

D, M = 96, 4


def quad_loss(params, batch):
    return 0.5 * jnp.mean(jnp.sum((params["x"] - batch["t"]) ** 2, -1))


def problem():
    params = {"x": jnp.linspace(-1.0, 1.0, D, dtype=jnp.float32)}
    batch = {"t": jnp.asarray(
        np.random.default_rng(0).normal(size=(2 * M, D)), jnp.float32)}
    return params, batch


def ho_cfg(**kw):
    kw.setdefault("tau", 4)
    kw.setdefault("mu", 1e-3)
    kw.setdefault("m", M)
    kw.setdefault("lr", 0.1)
    kw.setdefault("zo_lr", 0.05)
    return HOSGDConfig(**kw)


def tree_equal(a, b):
    return all(bool(jnp.array_equal(x, y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# --------------------------------------------------------------------------- #
# claim 1: the lowered programs ARE the PR-2 programs (references preserved
# verbatim from the pre-IR core/distributed.py)
# --------------------------------------------------------------------------- #
def _pr2_fo_step(loss_fn, opt):
    """PR-2 make_fo_step body (grad_accum=1, no compressor), verbatim."""

    def fo_step(t, params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        deltas, opt_state = opt.update(grads, opt_state, params, t)
        return apply_deltas(params, deltas), opt_state, loss

    return fo_step


def _pr2_zo_step(loss_fn, ho, opt, m):
    """PR-2 make_zo_step's 0.4.x auto-sharded fallback (unrolled), verbatim."""

    def engine_for(params):
        return make_engine(ho.engine, params, ho.seed, acc_dtype=ho.acc_dtype)

    def zo_step(t, params, opt_state, batch):
        eng = engine_for(params)
        workers = jnp.arange(m, dtype=jnp.uint32)
        stacked = jax.tree.map(
            lambda x: x.reshape(m, x.shape[0] // m, *x.shape[1:]), batch)
        cs, f0s = eng.zo_coeffs(loss_fn, params, stacked, t, workers, ho.mu)
        rec = eng.reconstruct(cs, t)
        g_hat = jax.tree.map(lambda a: a * (ho.zo_scale / m), rec)
        loss = jnp.mean(f0s)
        deltas, opt_state = opt.update(g_hat, opt_state, params, t)
        return apply_deltas(params, deltas), opt_state, loss

    return zo_step


def test_lowered_fo_step_bit_identical_to_pr2():
    params, batch = problem()
    mesh = make_test_mesh(data=1, model=1)
    opt = sgd(const_schedule(0.1))
    new = jax.jit(make_fo_step(quad_loss, mesh, opt))
    ref = jax.jit(_pr2_fo_step(quad_loss, opt))
    sn, so = params, opt.init(params)
    rn, ro = params, opt.init(params)
    for t in range(4):
        sn, so, ln = new(jnp.int32(t), sn, so, batch)
        rn, ro, lr_ = ref(jnp.int32(t), rn, ro, batch)
        assert float(ln) == float(lr_)
        assert tree_equal(sn, rn), f"fo params diverged at t={t}"
    assert tree_equal(so, ro)


@pytest.mark.parametrize("buckets", [1, 2, 8, 5])
def test_bucketed_fo_step_bit_identical_to_pr2(buckets):
    """The bucketed all-reduce lowering (``--fo-buckets``) is pure data
    movement: slicing the flat gradient into ceil-sized chunks (B=5 over
    D=96 exercises the uneven 20/20/20/20/16 tail) and reassembling must be
    BIT-identical to the unbucketed PR-2 step — losses, params and optimizer
    state, every step."""
    params, batch = problem()
    mesh = make_test_mesh(data=1, model=1)
    opt = sgd(const_schedule(0.1))
    new = jax.jit(make_fo_step(quad_loss, mesh, opt, buckets=buckets))
    ref = jax.jit(_pr2_fo_step(quad_loss, opt))
    sn, so = params, opt.init(params)
    rn, ro = params, opt.init(params)
    for t in range(4):
        sn, so, ln = new(jnp.int32(t), sn, so, batch)
        rn, ro, lr_ = ref(jnp.int32(t), rn, ro, batch)
        assert float(ln) == float(lr_)
        assert tree_equal(sn, rn), f"bucketed fo diverged at t={t} B={buckets}"
    assert tree_equal(so, ro)


def test_bucketed_reduce_form_chunks_and_reassembles():
    """_bucketed_reduce_form is the identity on any tree, including uneven
    last buckets and bucket counts exceeding the parameter count."""
    from repro.core.distributed import _bucketed_reduce_form

    tree = {"a": jnp.linspace(0, 1, 7, dtype=jnp.float32),
            "b": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)}
    for b in (1, 2, 5, 13, 64):
        out = _bucketed_reduce_form(tree, b)
        assert tree_equal(out, tree), f"buckets={b}"


@pytest.mark.parametrize("engine", ["tree", "fused"])
def test_lowered_zo_step_bit_identical_to_pr2(engine):
    params, batch = problem()
    mesh = make_test_mesh(data=1, model=1)
    ho = ho_cfg(engine=engine)
    opt = sgd(const_schedule(ho.lr))
    new = jax.jit(make_zo_step(quad_loss, mesh, ho, opt, m=M))
    ref = jax.jit(_pr2_zo_step(quad_loss, ho, opt, M))
    sn, so = params, opt.init(params)
    rn, ro = params, opt.init(params)
    for t in range(1, 5):
        sn, so, ln = new(jnp.int32(t), sn, so, batch)
        rn, ro, lr_ = ref(jnp.int32(t), rn, ro, batch)
        assert float(ln) == float(lr_)
        assert tree_equal(sn, rn), f"zo params diverged at t={t}"


def test_ho_program_schedule_matches_monolithic_decision():
    """round_for's FO/ZO schedule (fixed, adaptive, zo_only) is the same
    host logic the monolithic step runs — orders and t_step agree."""
    from repro.core.ho_sgd import adaptive_tau_decision

    ho = ho_cfg(tau=4)
    sched = lambda t: 2 + t // 3
    prog = R.ho_sgd_program(quad_loss, ho, tau_schedule=sched)
    state = {"opt": (), "since_fo": 0}
    since = 0
    for t in range(12):
        rs = prog.round_for(t, {**state, "since_fo": since})
        is_fo, t_step, since2 = adaptive_tau_decision(t, since, sched(t),
                                                      ho.tau)
        assert (rs.round.order == 1) == is_fo
        assert rs.t_step == t_step
        assert rs.host_updates["since_fo"] == since2
        since = since2
    zo_prog = R.ho_sgd_program(quad_loss, ho, zo_only=True)
    for t in range(5):
        assert zo_prog.round_for(t, {"since_fo": t}).round.order == 0


# --------------------------------------------------------------------------- #
# claim 2: the reference executor vs the single-host reference
# --------------------------------------------------------------------------- #
def test_executor_matches_single_host_reference():
    """RoundExecutor over all m workers == make_ho_sgd, within the engine
    suite's cross-program tolerances (the executor vmaps the coefficient
    evals; the reference unrolls them — documented ulp drift, not FMA-free
    bitwise territory)."""
    params, batch = problem()
    ho = ho_cfg()
    prog = R.ho_sgd_program(quad_loss, ho)
    ex = R.RoundExecutor(prog)
    ref = make_ho_sgd(quad_loss, ho)

    ps, st = params, prog.init(params)
    pr, sr = params, ref.init(params)
    for t in range(6):
        ps, st, me = ex.run(t, ps, st, batch)
        pr, sr, mr = ref.step(t, pr, sr, batch)
        assert me["order"] == mr["order"]
        np.testing.assert_allclose(float(me["loss"]), float(mr["loss"]),
                                   rtol=1e-5)
        for a, b in zip(jax.tree.leaves(ps), jax.tree.leaves(pr)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)


def test_executor_zo_subset_uses_only_live_workers():
    """A ZO round over workers {0, 2} reconstructs from exactly those two
    directions, scaled by the LIVE count — the manual engine computation
    reproduces it."""
    params, batch = problem()
    ho = ho_cfg()
    opt = sgd(const_schedule(ho.lr))
    prog = R.ho_sgd_program(quad_loss, ho, opt)
    ex = R.RoundExecutor(prog)
    state = prog.init(params)
    t = 1                                     # a ZO round (tau=4)
    live = [0, 2]
    ps, _, met = ex.run(t, params, state, batch, workers=live)
    assert met["order"] == 0 and met["comm_bytes"] == 4 * len(live)

    # manual: same vmapped coefficient evals, reconstruct over the live set
    eng = make_engine(ho.engine, params, ho.seed, acc_dtype=ho.acc_dtype)
    shards = R.split_shards(batch, M)
    w_arr = jnp.asarray(live, jnp.uint32)
    sel = jax.tree.map(lambda x: x[jnp.asarray(live)], shards)
    cs, _ = jax.vmap(
        lambda w, b: eng.zo_coeff(quad_loss, params, b, jnp.int32(t), w,
                                  ho.mu))(w_arr, sel)
    rec = eng.reconstruct(cs, jnp.int32(t), w_arr)
    g_hat = jax.tree.map(lambda a: a * (ho.zo_scale / len(live)), rec)
    deltas, _ = opt.update(g_hat, opt.init(params), params, jnp.int32(t))
    expect = apply_deltas(params, deltas)
    for a, b in zip(jax.tree.leaves(ps), jax.tree.leaves(expect)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)
    # and it genuinely differs from the full-membership round
    ps_full, _, _ = ex.run(t, params, state, batch)
    assert not tree_equal(ps, ps_full)


def test_executor_fo_subset_averages_live_shards_only():
    params, batch = problem()
    ho = ho_cfg()
    opt = sgd(const_schedule(ho.lr))
    prog = R.ho_sgd_program(quad_loss, ho, opt)
    ex = R.RoundExecutor(prog)
    state = prog.init(params)
    live = [1, 3]
    ps, _, met = ex.run(0, params, state, batch, workers=live)
    assert met["order"] == 1 and met["comm_bytes"] == 4 * D

    shards = R.split_shards(batch, M)
    grads = [jax.grad(quad_loss)(params, R._slice_tree(shards, w))
             for w in live]
    g = jax.tree.map(
        lambda *xs: jnp.mean(jnp.stack([x.astype(jnp.float32) for x in xs]),
                             0).astype(xs[0].dtype), *grads)
    deltas, _ = opt.update(g, opt.init(params), params, jnp.int32(0))
    expect = apply_deltas(params, deltas)
    for a, b in zip(jax.tree.leaves(ps), jax.tree.leaves(expect)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)


def test_executor_zo_stale_views_change_the_coefficients():
    """Feeding a worker a stale params view changes its coefficient — the
    bounded-staleness replay's divergence mechanism."""
    params, batch = problem()
    ho = ho_cfg()
    prog = R.ho_sgd_program(quad_loss, ho)
    ex = R.RoundExecutor(prog)
    state = prog.init(params)
    stale = jax.tree.map(lambda x: x + 0.25, params)
    cur, _, _ = ex.run(1, params, state, batch)
    lag, _, _ = ex.run(1, params, state, batch, views={2: stale})
    assert not tree_equal(cur, lag)


# --------------------------------------------------------------------------- #
# collective semantics of the executor
# --------------------------------------------------------------------------- #
def test_neighbor_mix_ring_closed_form():
    st = {"v": jnp.arange(4.0)[:, None]}
    out = np.asarray(R.neighbor_mix(st, 4)["v"][:, 0])
    np.testing.assert_allclose(out, [(3 + 0 + 1) / 3, (0 + 1 + 2) / 3,
                                     (1 + 2 + 3) / 3, (2 + 3 + 0) / 3])
    out2 = np.asarray(R.neighbor_mix({"v": jnp.arange(2.0)[:, None]},
                                     2)["v"][:, 0])
    np.testing.assert_allclose(out2, [0.5, 0.5])
    one = R.neighbor_mix({"v": jnp.ones((1, 3))}, 1)
    np.testing.assert_allclose(np.asarray(one["v"]), 1.0)


def test_gossip_pa_round_mixes_ring_neighbors():
    """One gossip averaging round leaves each replica at the ring mean of
    its neighborhood (closed form on replicas pinned to distinct values)."""
    from repro.core.baselines import pa_sgd_program

    prog = pa_sgd_program(quad_loss, M, tau=1, lr=0.0, gossip=True)
    params, batch = problem()
    state = prog.init(params)
    # pin replica w to the constant w
    state = {"replicas": jax.tree.map(
        lambda x: jnp.broadcast_to(
            jnp.arange(M, dtype=x.dtype).reshape(M, *([1] * (x.ndim - 1))),
            x.shape).copy(), state["replicas"])}
    ex = R.RoundExecutor(prog)
    _, st2, met = ex.run(0, params, state, batch)   # lr=0: pure mixing
    assert met["comm_bytes"] == 2 * 4 * D           # two neighbor models
    got = np.asarray(st2["replicas"]["x"][:, 0])
    np.testing.assert_allclose(got, [(3 + 0 + 1) / 3, (0 + 1 + 2) / 3,
                                     (1 + 2 + 3) / 3, (2 + 3 + 0) / 3],
                               rtol=1e-6)


def test_wire_modes_book_per_worker_vs_legacy_bytes():
    from repro.dist.compress import qsgd

    codec = qsgd(8)
    payload = {"x": jnp.zeros((D,), jnp.float32)}
    rnd_pw = R.Round("r", 1, "all_reduce", lambda *a: None, lambda *a: None,
                     wire=R.Wire(codec, "per_worker"))
    rnd_lg = R.Round("r", 1, "all_reduce", lambda *a: None, lambda *a: None,
                     wire=R.Wire(codec, "legacy"))
    assert R.wire_nbytes(rnd_pw, payload, 4) == codec.nbytes(D) * 4
    assert R.wire_nbytes(rnd_lg, payload, 4) == codec.nbytes(D)
    dense = R.Round("r", 1, "all_reduce", lambda *a: None, lambda *a: None)
    assert R.wire_nbytes(dense, payload, 4) == 4 * D
    gather = R.Round("r", 0, "all_gather", lambda *a: None, lambda *a: None)
    assert R.wire_nbytes(gather, {"c": jnp.zeros((), jnp.float32)}, 3) == 12


# --------------------------------------------------------------------------- #
# PR-9 regressions: executor cache keying + wire-codec collective matrix
# --------------------------------------------------------------------------- #
def test_executor_jit_cache_keyed_by_round_object():
    """Regression: the executor's jit caches were keyed by ``id(rnd)``
    without holding the round — a dynamically rebuilt round could alias a
    dead round's id and silently run the STALE jitted local.  Build fresh
    rounds in a loop (dropping each old one first so CPython reuses the
    address) and pin that round ``i``'s local actually runs at step ``i``."""
    import gc

    def make_round(i):
        def local(t, worker, model, shard):
            return jnp.full((2,), float(i)), jnp.zeros(())

        def apply(t, params, state, reduced, workers, aux):
            return params, state, {"val": reduced[0, 0]}

        return R.Round(f"c{i}", 1, "none", local, apply)

    cell = {"rnd": None}
    prog = R.RoundProgram(
        "cache", 1, lambda p: {},
        lambda t, state: R.RoundStep(cell["rnd"], t, {}),
        lambda t: 0.0, lambda t: 0.0, lambda t: 0.0)
    ex = R.RoundExecutor(prog)
    params = {"x": jnp.zeros((2,), jnp.float32)}
    batch = {"t": jnp.zeros((1, 2), jnp.float32)}
    for i in range(20):
        cell["rnd"] = None      # drop the old round so its id can be reused
        gc.collect()
        cell["rnd"] = make_round(i)
        _, _, met = ex.run(0, params, {}, batch)
        assert float(met["val"]) == float(i), \
            f"stale jitted local: step {i} ran round {int(met['val'])}"


def test_wire_codec_collective_matrix():
    """Regression: ``wire_nbytes``/``reduce_payloads`` silently IGNORED the
    wire codec on all_gather and tree_average rounds — a configured
    compressor changed neither bytes nor math.  Now unsupported pairs
    fail fast at construction and tree_average implements the codec."""
    from repro.dist.compress import qsgd, signsgd

    noop = lambda *a: None
    # unsupported (collective, codec) pairs fail fast, naming the matrix
    for coll in ("all_gather", "none"):
        with pytest.raises(AssertionError, match="Wire codec"):
            R.Round("r", 0, coll, noop, noop, wire=R.Wire(qsgd(8)))
    # tree_average books the codec (per-worker and legacy modes)...
    codec = qsgd(8)
    payload = {"x": jnp.zeros((D,), jnp.float32)}
    ta_pw = R.Round("r", 1, "tree_average", noop, noop,
                    wire=R.Wire(codec, "per_worker"))
    ta_lg = R.Round("r", 1, "tree_average", noop, noop,
                    wire=R.Wire(codec, "legacy"))
    assert R.wire_nbytes(ta_pw, payload, 4) == codec.nbytes(D) * 4
    assert R.wire_nbytes(ta_lg, payload, 4) == codec.nbytes(D)
    # ...and the reduction actually routes through the codec: a signsgd
    # roundtrip per worker then mean != the plain mean the old code produced
    sg = R.Round("r", 1, "tree_average", noop, noop,
                 wire=R.Wire(signsgd(), "per_worker"))
    stacked = jnp.asarray([[0.5, -2.0], [1.5, -0.25]], jnp.float32)
    got = R.reduce_payloads(sg, stacked, [0, 1], jax.random.key(0))
    # worker roundtrips: [1.25, -1.25] and [0.875, -0.875] -> mean
    np.testing.assert_allclose(np.asarray(got), [1.0625, -1.0625], rtol=1e-6)
    plain = np.asarray(jnp.mean(stacked, 0))
    assert not np.allclose(np.asarray(got), plain)
