"""Table 1 cost model: per-iteration orders for every method, and agreement
between the analytic formulas and the CommLedger's measured bytes."""
import jax.numpy as jnp
import pytest

from repro.core import make_ho_sgd, make_pa_sgd, make_sync_sgd, make_zo_sgd
from repro.core.ho_sgd import HOSGDConfig
from repro.metrics import comm_report


def quad_loss(params, batch):
    return 0.5 * jnp.mean(jnp.sum((params["x"] - batch["t"]) ** 2, -1))


D, M, TAU = 10_000, 4, 8


def test_ho_sgd_per_iteration_orders():
    meth = make_ho_sgd(quad_loss, HOSGDConfig(tau=TAU, m=M, lr=0.1))
    assert meth.comm_scalars(D) == pytest.approx((TAU - 1 + D) / TAU)
    assert meth.fevals(D) == pytest.approx(2 * (TAU - 1) / TAU)
    assert meth.gevals(D) == pytest.approx(1 / TAU)


def test_sync_sgd_per_iteration_orders():
    meth = make_sync_sgd(quad_loss, M, lr=0.1)
    assert meth.comm_scalars(D) == D      # the full gradient, every iteration
    assert meth.fevals(D) == 0.0
    assert meth.gevals(D) == 1.0


def test_zo_sgd_per_iteration_orders():
    meth = make_zo_sgd(quad_loss, M, mu=1e-3, lr=0.1)
    assert meth.comm_scalars(D) == 1.0    # one scalar — independent of d
    assert meth.fevals(D) == 2.0
    assert meth.gevals(D) == 0.0


def test_pa_sgd_per_iteration_orders():
    meth = make_pa_sgd(quad_loss, M, tau=TAU, lr=0.1)
    assert meth.comm_scalars(D) == pytest.approx(D / TAU)   # model averaging
    assert meth.fevals(D) == 0.0
    assert meth.gevals(D) == 1.0          # full local gradient every iteration


def test_spectrum_ordering_in_d():
    """HO-SGD sits between sync-SGD (d) and ZO-SGD (O(1)) for large d."""
    ho = make_ho_sgd(quad_loss, HOSGDConfig(tau=TAU, m=M, lr=0.1))
    sync = make_sync_sgd(quad_loss, M, lr=0.1)
    zo = make_zo_sgd(quad_loss, M, mu=1e-3, lr=0.1)
    assert zo.comm_scalars(D) < ho.comm_scalars(D) < sync.comm_scalars(D)
    assert ho.comm_scalars(D) == pytest.approx(sync.comm_scalars(D) / TAU,
                                               rel=1e-2)


def test_ledger_agrees_with_analytic_formulas():
    """Drive the real distributed steps; comm_report's measured == analytic."""
    import jax
    from repro.core.distributed import make_distributed_ho_sgd
    from repro.dist import CommLedger
    from repro.launch.mesh import make_test_mesh
    from repro.opt.optimizers import const_schedule, sgd

    mesh = make_test_mesh(data=1, model=1)
    d, m, tau = 64, 1, 4
    ho = HOSGDConfig(tau=tau, mu=1e-3, m=m, lr=0.05, zo_lr=0.05 / d)
    opt = sgd(const_schedule(ho.lr))
    fo, zo = make_distributed_ho_sgd(quad_loss, mesh, ho, opt)
    ledger = CommLedger()
    fo_j, zo_j = ledger.wrap("fo", jax.jit(fo)), ledger.wrap("zo", jax.jit(zo))
    params = {"x": jnp.zeros((d,), jnp.float32)}
    state = opt.init(params)
    batch = {"t": jnp.ones((4, d), jnp.float32)}
    for t in range(2 * tau):
        step = fo_j if t % tau == 0 else zo_j
        params, state, _ = step(jnp.int32(t), params, state, batch)

    assert ledger.bytes_per_step("fo") == 4 * d
    assert ledger.bytes_per_step("zo") == 4 * m
    measured = ledger.total_bytes() / (2 * tau)
    analytic = 4 * (d + (tau - 1) * m) / tau
    assert measured == pytest.approx(analytic)
    lines = comm_report(ledger, d=d, m=m, tau=tau)
    assert any("fo_bytes_per_step,measured=256,analytic=256" in l
               for l in lines)
