"""Attention semantics: windows, decode cache slicing, encoder mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import attention as A
from repro.models import transformer as T

KEY = jax.random.key(0)


def _cfg(**kw):
    return get_config("qwen3-14b").reduced().with_(remat=False, **kw)


def test_window_limits_context():
    """With window W, logits at position i ignore keys before i-W+1."""
    cfg = _cfg(attn_chunk=0)
    p = A.init_attention(KEY, cfg, jnp.float32)
    S, W = 24, 4
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (1, S, cfg.d_model)) * 0.2
    out_w = A.attention_forward(cfg, p, x, jnp.int32(W))
    # perturb a token far outside every later window
    x2 = x.at[:, 2].set(5.0)
    out_w2 = A.attention_forward(cfg, p, x2, jnp.int32(W))
    # positions >= 2+W see no difference; positions < 2+W do
    np.testing.assert_allclose(np.asarray(out_w[:, 2 + W:]),
                               np.asarray(out_w2[:, 2 + W:]), atol=1e-5)
    assert bool(jnp.any(jnp.abs(out_w[:, 2] - out_w2[:, 2]) > 1e-3))


def test_decode_static_window_slice_matches_masked_full():
    """The long-context decode fast path (dynamic_slice of the last W cache
    slots) must equal masked full-cache attention."""
    cfg = _cfg()
    p = A.init_attention(KEY, cfg, jnp.float32)
    B, S, W = 2, 32, 8
    k = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, cfg.n_kv_heads, 32))
    v = jax.random.normal(jax.random.fold_in(KEY, 3), (B, S, cfg.n_kv_heads, 32))
    x = jax.random.normal(jax.random.fold_in(KEY, 4), (B, 1, cfg.d_model)) * 0.2
    for pos in (3, 7, 20, 31):
        full, _ = A.attention_decode(cfg, p, x, (k, v), jnp.int32(pos),
                                     window=jnp.int32(W))
        sliced, _ = A.attention_decode(cfg, p, x, (k, v), jnp.int32(pos),
                                       window=jnp.int32(W), static_window=W)
        np.testing.assert_allclose(np.asarray(full), np.asarray(sliced),
                                   rtol=1e-4, atol=1e-5, err_msg=f"pos={pos}")


def test_long_context_variant_decode_consistency():
    """gemma2's long_500k SWA variant: step-by-step decode == forward."""
    cfg = get_config("gemma2-2b").reduced().with_(
        remat=False, long_context=True)
    assert cfg.subquadratic
    params = T.init_model(jax.random.key(5), cfg)
    B, S = 1, 20
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    full_logits, _ = T.forward_logits(cfg, params, {"tokens": toks})
    caches = T.init_caches(cfg, B, S, jnp.float32)
    outs = []
    for t in range(S):
        lg, caches = T.decode_step(cfg, params, toks[:, t], jnp.int32(t), caches)
        outs.append(lg)
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(full_logits), rtol=3e-3, atol=3e-3)


def test_encoder_attention_is_bidirectional():
    cfg = get_config("hubert-xlarge").reduced().with_(remat=False, attn_chunk=0)
    p = A.init_attention(KEY, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 6), (1, 12, cfg.d_model)) * 0.2
    out = A.attention_forward(cfg, p, x)
    # changing a FUTURE token changes the FIRST position's output
    x2 = x.at[:, 11].set(3.0)
    out2 = A.attention_forward(cfg, p, x2)
    assert bool(jnp.any(jnp.abs(out[:, 0] - out2[:, 0]) > 1e-4))


def test_adaptive_tau_beyond_paper():
    from repro.core.ho_sgd import HOSGDConfig, make_adaptive_ho_sgd, run_method
    def quad_loss(params, batch):
        return 0.5 * jnp.mean(jnp.sum((params["x"] - batch["t"]) ** 2, -1))
    rng = np.random.default_rng(0)
    def batches():
        while True:
            yield {"t": (1.0 + 0.1 * rng.normal(size=(16, 32))).astype(np.float32)}
    meth = make_adaptive_ho_sgd(
        quad_loss, HOSGDConfig(tau=8, mu=1e-4, m=4, lr=0.3, zo_lr=0.3 / 16),
        tau_schedule=lambda t: 2 + t // 20)
    hist = run_method(meth, {"x": jnp.zeros((32,))}, batches(), 80)
    final = float(quad_loss(hist["params"], {"t": np.ones((1, 32), np.float32)}))
    assert final < 0.1, final
    assert 1 in hist["order"] and 0 in hist["order"]
