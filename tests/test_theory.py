"""Theorem 1 / Table 1 analytic reproductions."""
import math

import pytest

from repro.core.theory import (
    Problem, convergence_order, min_iterations, table1_row, theorem1_bound,
    theorem_mu,
)


def test_bound_decreases_with_N():
    p1 = Problem(d=1000, m=8, B=16, N=10_000_000)
    p2 = Problem(d=1000, m=8, B=16, N=40_000_000)
    b1 = theorem1_bound(p1, tau=8)["total"]
    b2 = theorem1_bound(p2, tau=8)["total"]
    assert b2 < b1
    # rate ~ 1/sqrt(N): quadrupling N halves the bound (within 10%)
    assert b2 == pytest.approx(b1 / 2, rel=0.15)


def test_tau1_drops_zo_terms():
    p = Problem(d=1000, m=8, B=16, N=10_000_000)
    b = theorem1_bound(p, tau=1)
    assert set(b) == {"fo_descent", "fo_variance", "total"}


def test_remark1_orders():
    p = Problem(d=500, m=4, B=8, N=1_000_000)
    assert convergence_order(p, tau=8) == pytest.approx(
        p.d / math.sqrt(p.m * p.N))
    assert convergence_order(p, tau=1) == pytest.approx(
        1 / math.sqrt(p.m * p.N))


def test_dominant_term_is_zo_variance_for_large_d():
    """Remark 2: the d*sigma^2 ZO-variance term dominates for tau>1."""
    p = Problem(d=100_000, m=8, B=16, N=10**9)
    b = theorem1_bound(p, tau=8)
    assert b["zo_variance_1"] == max(
        v for k, v in b.items() if k != "total")


def test_min_iterations_condition():
    p = Problem(d=900, m=5, B=5, N=0)
    n = min_iterations(p)
    assert n > 16 * (900 + 25 - 1) ** 2 / 25 - 1
    assert theorem_mu(Problem(d=900, m=5, B=5, N=n)) <= 1 / math.sqrt(900 * n) + 1e-12


def test_table1_comm_ordering():
    """Comm per iter: ZO (1) < HO ((tau-1+d)/tau) < RI (d/tau, tau<d) < sync (d)."""
    p = Problem(d=1_690_000, m=4, B=64, N=100_000)
    tau = 8
    comm = {k: table1_row(k, p, tau=tau)["comm"] for k in
            ("zo_sgd", "ho_sgd", "ri_sgd", "sync_sgd")}
    assert comm["zo_sgd"] < comm["ho_sgd"] < comm["sync_sgd"]
    assert comm["ri_sgd"] < comm["sync_sgd"]
    # the paper's ratio claim: HO comm = (1 + (tau-1)/d) x RI-SGD's d/tau
    assert comm["ho_sgd"] / comm["ri_sgd"] == pytest.approx(
        1 + (tau - 1) / p.d, rel=1e-6)


def test_table1_compute_ordering():
    """Normalized compute: ZO (1/d) < HO (1/tau + 1/d) < sync (1) < RI (1+mu*m)."""
    p = Problem(d=1_690_000, m=4, B=64, N=100_000)
    comp = {k: table1_row(k, p, tau=8)["comp"] for k in
            ("zo_sgd", "ho_sgd", "sync_sgd", "ri_sgd")}
    assert comp["zo_sgd"] < comp["ho_sgd"] < comp["sync_sgd"] < comp["ri_sgd"]
