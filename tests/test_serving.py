"""Serving engine: batched generate, greedy determinism, cache handling."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serving import Engine, ServeConfig


@pytest.fixture(scope="module")
def engine():
    cfg = get_config("qwen3-14b").reduced().with_(remat=False)
    params = T.init_model(jax.random.key(0), cfg)
    return cfg, params, Engine(cfg, params, ServeConfig(max_seq=48))


def test_generate_batched(engine):
    cfg, params, eng = engine
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(0, cfg.vocab_size, n)) for n in (5, 9, 3, 7)]
    outs = eng.generate(prompts, max_new=6)
    assert len(outs) == 4
    for p, o in zip(prompts, outs):
        assert o[: len(p)] == p
        assert len(o) == len(p) + 6
        assert all(0 <= t < cfg.vocab_size for t in o)


def test_generate_greedy_deterministic(engine):
    cfg, params, eng = engine
    prompts = [[1, 2, 3, 4], [5, 6, 7]]
    a = eng.generate(prompts, max_new=5)
    b = eng.generate(prompts, max_new=5)
    assert a == b


def test_generate_temperature_uses_key(engine):
    cfg, params, _ = engine
    eng = Engine(cfg, params, ServeConfig(max_seq=48, temperature=1.0))
    prompts = [[1, 2, 3]]
    a = eng.generate(prompts, max_new=8, key=jax.random.key(0))
    b = eng.generate(prompts, max_new=8, key=jax.random.key(1))
    assert a != b  # overwhelmingly likely with a random model


def test_generate_matches_forward_greedy():
    """Engine's first generated token == argmax of the model's forward."""
    import jax.numpy as jnp
    cfg = get_config("gemma2-2b").reduced().with_(remat=False)
    params = T.init_model(jax.random.key(1), cfg)
    eng = Engine(cfg, params, ServeConfig(max_seq=32))
    prompt = [3, 1, 4, 1, 5]
    out = eng.generate([prompt], max_new=1)[0]
    logits, _ = T.forward_logits(
        cfg, params, {"tokens": jnp.asarray([prompt], jnp.int32)})
    assert out[-1] == int(jnp.argmax(logits[0, -1]))
