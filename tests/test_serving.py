"""repro.serving: continuous batching, slotted KV cache, traffic model.

Pins (ISSUE 6):
  (a) the continuous-batching engine is token-identical at temperature 0 to
      the VERBATIM seed synchronous engine (tests/helpers/
      seed_serving_reference.py) run per-request — and to the seed BATCHED
      path when prompts share one length (equal lengths mean no left-pad
      contamination, so the two seed modes agree);
  (b) prefill-then-decode equals the teacher-forced full forward per
      position;
  (c) slot alloc/evict invariants hold under randomized admit/retire;
  (d) the traffic model is deterministic: same spec seed => bit-identical
      event trace and latency table.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serving import Engine, SlotKVCache, ServeConfig, sample_key
from repro.sim.traffic import (
    NO_OVERHEADS,
    StepOverheads,
    TrafficSpec,
    poisson_trace,
    replay,
    replay_seed_sync,
    serve_compute_model,
)
from tests.helpers.seed_serving_reference import SeedEngine, SeedServeConfig

MAX_SEQ = 48


@pytest.fixture(scope="module")
def qwen():
    cfg = get_config("qwen3-14b").reduced().with_(remat=False)
    return cfg, T.init_model(jax.random.key(0), cfg)


@pytest.fixture(scope="module")
def gemma():
    cfg = get_config("gemma2-2b").reduced().with_(remat=False)
    return cfg, T.init_model(jax.random.key(1), cfg)


def mixed_prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [list(map(int, rng.integers(0, cfg.vocab_size, n))) for n in lens]


# --------------------------------------------------------------------------- #
# seed-era behavior kept
# --------------------------------------------------------------------------- #
def test_generate_batched(qwen):
    cfg, params = qwen
    eng = Engine(cfg, params, ServeConfig(max_seq=MAX_SEQ, slots=3))
    prompts = mixed_prompts(cfg, (5, 9, 3, 7))
    outs = eng.generate(prompts, max_new=6)
    assert len(outs) == 4
    for p, o in zip(prompts, outs):
        assert o[: len(p)] == p
        assert len(o) == len(p) + 6
        assert all(0 <= t < cfg.vocab_size for t in o)


def test_generate_greedy_deterministic(qwen):
    cfg, params = qwen
    eng = Engine(cfg, params, ServeConfig(max_seq=MAX_SEQ, slots=2))
    prompts = [[1, 2, 3, 4], [5, 6, 7]]
    assert eng.generate(prompts, max_new=5) == eng.generate(prompts, max_new=5)


def test_generate_temperature_uses_key(qwen):
    cfg, params = qwen
    eng = Engine(cfg, params, ServeConfig(max_seq=MAX_SEQ, temperature=1.0))
    prompts = [[1, 2, 3]]
    a = eng.generate(prompts, max_new=8, key=jax.random.key(0))
    b = eng.generate(prompts, max_new=8, key=jax.random.key(1))
    assert a != b  # overwhelmingly likely with a random model
    # same key on the same engine resamples identically (per-(request, step)
    # keys are derived from the position in the call, not the global rid)
    assert a == eng.generate(prompts, max_new=8, key=jax.random.key(0))


def test_generate_matches_forward_greedy(gemma):
    cfg, params = gemma
    eng = Engine(cfg, params, ServeConfig(max_seq=32))
    prompt = [3, 1, 4, 1, 5]
    out = eng.generate([prompt], max_new=1)[0]
    logits, _ = T.forward_logits(
        cfg, params, {"tokens": jnp.asarray([prompt], jnp.int32)})
    assert out[-1] == int(jnp.argmax(logits[0, -1]))


# --------------------------------------------------------------------------- #
# (a) token identity vs the verbatim seed engine
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("fixture", ["qwen", "gemma"])
def test_token_identity_vs_seed_per_request(fixture, request):
    """Continuous batching (slots < requests, mid-decode admission) produces
    the seed engine's exact temperature-0 token streams, request by request.
    (Per-request B=1 seed runs: the seed's batched mode left-pads, so short
    prompts in a mixed batch attend pad tokens — that contamination is a
    seed artifact, not a target.)"""
    cfg, params = request.getfixturevalue(fixture)
    eng = Engine(cfg, params, ServeConfig(max_seq=MAX_SEQ, slots=3))
    seed = SeedEngine(cfg, params, SeedServeConfig(max_seq=MAX_SEQ))
    prompts = mixed_prompts(cfg, (5, 9, 3, 7, 12, 4, 16, 6), seed=2)
    outs = eng.generate(prompts, max_new=8)
    for p, o in zip(prompts, outs):
        assert o == seed.generate([p], max_new=8)[0]


def test_token_identity_vs_seed_batched_equal_lengths(qwen):
    """With one shared prompt length the seed batched path has no pad
    contamination, so the continuous engine must match it batch-for-batch."""
    cfg, params = qwen
    eng = Engine(cfg, params, ServeConfig(max_seq=MAX_SEQ, slots=4))
    seed = SeedEngine(cfg, params, SeedServeConfig(max_seq=MAX_SEQ))
    prompts = mixed_prompts(cfg, (6, 6, 6, 6), seed=3)
    assert eng.generate(prompts, max_new=7) == seed.generate(prompts, max_new=7)


def test_token_identity_ssm_exact_length_prefill():
    """SSM configs prefill at exact length (pad tokens would corrupt the
    post-prompt state); the slot-pool decode still matches the seed."""
    cfg = get_config("falcon-mamba-7b").reduced().with_(remat=False)
    params = T.init_model(jax.random.key(2), cfg)
    eng = Engine(cfg, params, ServeConfig(max_seq=32, slots=2))
    seed = SeedEngine(cfg, params, SeedServeConfig(max_seq=32))
    prompts = mixed_prompts(cfg, (5, 9, 3), seed=4)
    outs = eng.generate(prompts, max_new=6)
    for p, o in zip(prompts, outs):
        assert o == seed.generate([p], max_new=6)[0]
    assert eng.scheduler.prefill_buckets() == (3, 5, 9)  # exact, not bucketed


def test_prefill_buckets_cached(qwen):
    cfg, params = qwen
    eng = Engine(cfg, params, ServeConfig(max_seq=MAX_SEQ, slots=4))
    eng.generate(mixed_prompts(cfg, (5, 9, 7)), max_new=2)
    # 5 and 7 share the 8-bucket: exactly two compiled prefill executables
    assert eng.scheduler.prefill_buckets() == (8, 16)


# --------------------------------------------------------------------------- #
# (b) prefill-then-decode == teacher-forced full forward
# --------------------------------------------------------------------------- #
def test_prefill_decode_matches_teacher_forced(qwen):
    cfg, params = qwen
    prompt = [3, 1, 4, 1, 5, 9, 2, 6, 5, 3]
    ref_logits, _ = T.forward_logits(
        cfg, params, {"tokens": jnp.asarray([prompt], jnp.int32)})
    # bucketed prefill of the first 4 tokens (padded to 8), logits at pos 3
    L0, bucket, S = 4, 8, 24
    toks = np.zeros((1, bucket), np.int32)
    toks[0, :L0] = prompt[:L0]
    lg, caches = T.prefill_at(
        cfg, params, {"tokens": jnp.asarray(toks)},
        jnp.asarray([L0 - 1], jnp.int32))
    assert int(jnp.argmax(lg[0])) == int(jnp.argmax(ref_logits[0, L0 - 1]))
    np.testing.assert_allclose(
        np.asarray(lg[0]), np.asarray(ref_logits[0, L0 - 1]), atol=1e-4)
    # teacher-force the rest through the slot pool (slot 1 of 3, others idle)
    pool = T.init_caches(cfg, 3, S, jnp.dtype(cfg.dtype))
    pool = jax.tree.map(
        lambda p, c: jax.lax.dynamic_update_slice(
            p, c.astype(p.dtype), (0, 1) + (0,) * (p.ndim - 2)),
        pool, caches)
    for step in range(L0, len(prompt)):
        tok = jnp.asarray([0, prompt[step], 0], jnp.int32)
        pos = jnp.asarray([-1, step, -1], jnp.int32)
        lg, pool = T.decode_step_slots(cfg, params, tok, pos, pool)
        assert int(jnp.argmax(lg[1])) == int(jnp.argmax(ref_logits[0, step]))
        np.testing.assert_allclose(
            np.asarray(lg[1]), np.asarray(ref_logits[0, step]), atol=1e-4)


# --------------------------------------------------------------------------- #
# (c) slot alloc/evict invariants under randomized admit/retire
# --------------------------------------------------------------------------- #
def test_slot_invariants_randomized(qwen):
    cfg, params = qwen
    pool = SlotKVCache(cfg, slots=4, max_seq=16)
    prefill = jax.jit(lambda p, b: T.prefill(cfg, p, b))
    rng = np.random.default_rng(0)
    live = {}
    next_rid = 0
    for _ in range(60):
        if live and (len(live) == pool.slots or rng.random() < 0.4):
            slot = rng.choice(sorted(live))
            del live[slot]
            pool.evict(int(slot))
        else:
            rid = next_rid
            next_rid += 1
            slot = pool.alloc(rid)
            assert slot is not None and slot not in live
            L = int(rng.integers(2, 8))
            _, caches = prefill(
                params, {"tokens": jnp.asarray([[rid % cfg.vocab_size] * L])})
            pool.assign(slot, caches, L)
            live[slot] = (rid, L, caches)
        pool.check_invariants()
        assert pool.free_slots == pool.slots - len(live)
    # gather returns exactly what was assigned to each live slot
    for slot, (rid, L, caches) in live.items():
        got = pool.gather([slot])
        k_got = np.asarray(got["k"][:, 0, :L])
        k_want = np.asarray(caches["k"][:, 0].astype(got["k"].dtype))
        np.testing.assert_array_equal(k_got, k_want)
    # exhaustion: filling the pool makes alloc return None
    while pool.free_slots:
        s = pool.alloc(10_000 + pool.free_slots)
        pool.assign(s, live[max(live)][2] if live else caches, 2)
    assert pool.alloc(99999) is None
    pool.evict(0)
    with pytest.raises(AssertionError):
        pool.evict(0)  # double-evict of an already-free slot


# --------------------------------------------------------------------------- #
# EOS + early exit (the dead seed ``eos_id`` is now honored)
# --------------------------------------------------------------------------- #
def test_eos_honored_and_slot_freed(qwen):
    cfg, params = qwen
    prompts = mixed_prompts(cfg, (5, 7, 4), seed=5)
    base = Engine(cfg, params, ServeConfig(max_seq=MAX_SEQ, slots=3))
    ref = base.generate(prompts, max_new=8)
    # pick a token request 0 emits as EOS; every stream must truncate at its
    # FIRST occurrence (requests that never emit it are unaffected)
    eos = ref[0][len(prompts[0]) + 3]
    eng = Engine(cfg, params,
                 ServeConfig(max_seq=MAX_SEQ, slots=3, eos_id=eos))
    outs = eng.generate(prompts, max_new=8)
    truncated = 0
    for i in range(3):
        gen = outs[i][len(prompts[i]):]
        ref_gen = ref[i][len(prompts[i]):]
        if eos in ref_gen:
            assert gen == ref_gen[: ref_gen.index(eos) + 1]
            assert gen[-1] == eos
            truncated += 1
        else:
            assert gen == ref_gen
    assert truncated >= 1
    assert eng.scheduler.pool.live_slots() == []  # every slot returned


def test_offline_early_exit_step_count(qwen):
    """EOS retirement ends the offline drain early — the seed always paid
    ``max_new`` decode iterations regardless."""
    cfg, params = qwen
    prompts = mixed_prompts(cfg, (5, 7), seed=6)
    base = Engine(cfg, params, ServeConfig(max_seq=MAX_SEQ, slots=2))
    ref = base.generate(prompts, max_new=10)
    eos = ref[0][len(prompts[0]) + 1]   # request 0's 2nd generated token

    def drain_steps(sc, reqs):
        eng = Engine(cfg, params, sc)
        for i, p in enumerate(reqs):
            eng.submit(p, 10, key_id=i)
        n = 0
        while eng.has_work:
            eng.step()
            n += 1
        return n

    full = drain_steps(ServeConfig(max_seq=MAX_SEQ, slots=2), prompts)
    # the admission step emits two tokens (prefill + same-step decode), then
    # max_new - 2 pure decode steps
    assert full == 9
    # with EOS at request 0's second token, serving request 0 alone drains in
    # a single step instead of nine
    early = drain_steps(
        ServeConfig(max_seq=MAX_SEQ, slots=2, eos_id=eos), prompts[:1])
    assert early == 1


# --------------------------------------------------------------------------- #
# canonical sampling keys: reproducible regardless of admission order
# --------------------------------------------------------------------------- #
def test_sampling_invariant_to_slot_count(qwen):
    """temperature>0 outputs depend only on (key, request index, step) — the
    same workload served through 1 slot and 4 slots (totally different
    admission/packing orders) samples identical token streams."""
    cfg, params = qwen
    prompts = mixed_prompts(cfg, (5, 9, 3, 7), seed=7)
    key = jax.random.key(42)
    outs = []
    for slots in (1, 4):
        eng = Engine(cfg, params,
                     ServeConfig(max_seq=MAX_SEQ, slots=slots, temperature=1.0))
        outs.append(eng.generate(prompts, max_new=6, key=key))
    assert outs[0] == outs[1]


def test_sample_key_single_fold_per_component(qwen):
    base = jax.random.key(0)
    k = sample_key(base, 3, 5)
    want = jax.random.fold_in(jax.random.fold_in(base, 3), 5)
    assert jnp.array_equal(jax.random.key_data(k), jax.random.key_data(want))
    # distinct across both components (the seed path collapsed step twice)
    assert not jnp.array_equal(jax.random.key_data(sample_key(base, 3, 6)),
                               jax.random.key_data(k))
    assert not jnp.array_equal(jax.random.key_data(sample_key(base, 4, 5)),
                               jax.random.key_data(k))


# --------------------------------------------------------------------------- #
# (d) traffic-model determinism + open-loop semantics
# --------------------------------------------------------------------------- #
def traffic_engine(cfg, params, spec, slots):
    return Engine(cfg, params,
                  ServeConfig(max_seq=spec.required_max_seq(), slots=slots))


def test_traffic_determinism(qwen):
    cfg, params = qwen
    spec = TrafficSpec(rate=300.0, n_requests=16, prompt_lens=(4, 9, 16),
                       out_lens=(3, 8), vocab=cfg.vocab_size, seed=11)
    cm = serve_compute_model(cfg, flops_per_sec=1e9)
    a = replay(traffic_engine(cfg, params, spec, 3), spec, cm)
    b = replay(traffic_engine(cfg, params, spec, 3), spec, cm)
    assert a.events == b.events        # bit-identical event trace
    assert a.rows == b.rows            # bit-identical latency table
    assert a.summary == b.summary
    # a different seed produces a different arrival trace
    spec2 = TrafficSpec(rate=300.0, n_requests=16, prompt_lens=(4, 9, 16),
                        out_lens=(3, 8), vocab=cfg.vocab_size, seed=12)
    c = replay(traffic_engine(cfg, params, spec2, 3), spec2, cm)
    assert c.events != a.events


def test_traffic_open_loop_arrivals(qwen):
    """Arrivals are independent of service: the arrival trace is identical
    whatever the slot count, and TTFT <= total latency per request."""
    cfg, params = qwen
    spec = TrafficSpec(rate=500.0, n_requests=12, prompt_lens=(4, 12),
                       out_lens=(4, 8), vocab=cfg.vocab_size, seed=13)
    cm = serve_compute_model(cfg, flops_per_sec=1e9)
    traces = []
    for slots in (1, 6):
        r = replay(traffic_engine(cfg, params, spec, slots), spec, cm)
        traces.append([e for e in r.events if e[0] == "arrive"])
        assert len(r.rows) == spec.n_requests
        for row in r.rows:
            assert 0.0 < row["ttft"] <= row["latency"]
        # greedy, no EOS: every request generates exactly its budget
        assert r.summary["total_tokens"] == float(
            sum(row["max_new"] for row in r.rows))
    assert traces[0] == traces[1]
    arr = poisson_trace(spec)
    assert [e[2] for e in traces[0]] == [a.t for a in arr]


def test_step_overheads_zero_default_and_determinism(qwen):
    """The zero-overhead default is bit-identical to explicit NO_OVERHEADS
    (every pre-overhead pin survives), and nonzero per-step overheads keep
    the determinism contract while strictly slowing the replay."""
    cfg, params = qwen
    spec = TrafficSpec(rate=300.0, n_requests=12, prompt_lens=(4, 9),
                       out_lens=(3, 8), vocab=cfg.vocab_size, seed=5)
    cm = serve_compute_model(cfg, flops_per_sec=1e9)
    base = replay(traffic_engine(cfg, params, spec, 3), spec, cm)
    explicit = replay(traffic_engine(cfg, params, spec, 3), spec, cm,
                      NO_OVERHEADS)
    assert base.events == explicit.events and base.rows == explicit.rows
    oh = StepOverheads(dispatch_s=1e-3, sample_s=2e-4)
    a = replay(traffic_engine(cfg, params, spec, 3), spec, cm, oh)
    b = replay(traffic_engine(cfg, params, spec, 3), spec, cm, oh)
    assert a.events == b.events and a.rows == b.rows and a.summary == b.summary
    assert a.summary["makespan_s"] > base.summary["makespan_s"]
    assert a.summary["tok_per_sec"] < base.summary["tok_per_sec"]
    assert a.summary["total_tokens"] == base.summary["total_tokens"]


def test_seed_sync_overhead_pricing_closed_form_and_amortization():
    """Per-step overheads on the seed synchronous path price EXACTLY
    dispatch per launch + sampling per decode step: the makespan delta vs
    the zero-overhead run equals the closed form summed over batch groups —
    and widening the batch amortizes it (fewer launches for the same
    tokens), which is the follow-up's whole point."""
    from repro.sim.costs import ComputeModel

    spec = TrafficSpec(rate=1e4, n_requests=12, prompt_lens=(4, 12),
                       out_lens=(4, 8), seed=7)
    # compute times (>= 4 ms per prefill) dwarf the ~1.2 ms arrival span, so
    # every group after the first starts clock-bound and the overhead delta
    # is purely additive
    cm = ComputeModel(fwd_flops=1e6, flops_per_sec=1e9)
    oh = StepOverheads(dispatch_s=2e-4, sample_s=5e-5)
    arr = poisson_trace(spec)
    deltas = {}
    for B in (1, 4):
        base = replay_seed_sync(spec, cm, batch=B)
        over = replay_seed_sync(spec, cm, batch=B, overheads=oh)
        groups = [arr[i:i + B] for i in range(0, len(arr), B)]
        expect = sum(oh.dispatch_s
                     + (max(a.max_new for a in g) - 1) * oh.decode_s
                     for g in groups)
        delta = over.summary["makespan_s"] - base.summary["makespan_s"]
        assert delta == pytest.approx(expect)
        assert over.summary["total_tokens"] == base.summary["total_tokens"]
        deltas[B] = delta
    assert deltas[4] < deltas[1]          # batching amortizes the overhead


def test_overheads_make_slots_axis_price_amortization(qwen):
    """With per-step fixed overheads the slots axis is no longer FLOP-flat:
    a decode step over more live slots spreads the same dispatch+sample cost
    over more tokens, so the wide engine's throughput advantage over the
    1-slot engine strictly GROWS when overheads turn on."""
    cfg, params = qwen
    spec = TrafficSpec(rate=500.0, n_requests=12, prompt_lens=(4, 12),
                       out_lens=(4, 8), vocab=cfg.vocab_size, seed=13)
    cm = serve_compute_model(cfg, flops_per_sec=1e9)
    oh = StepOverheads(dispatch_s=1e-3, sample_s=2e-4)

    def tps(slots, overheads):
        r = replay(traffic_engine(cfg, params, spec, slots), spec, cm,
                   overheads)
        return r.summary["tok_per_sec"]

    gain_flat = tps(6, NO_OVERHEADS) / tps(1, NO_OVERHEADS)
    gain_oh = tps(6, oh) / tps(1, oh)
    assert gain_oh > gain_flat


def test_traffic_continuous_beats_seed_sync(qwen):
    """The acceptance-criterion ordering, pinned at test scale: on a mixed
    open-loop workload the continuous engine clears strictly more tokens/sec
    than the priced seed synchronous batch path at equal batch width."""
    cfg, params = qwen
    spec = TrafficSpec.from_mix(rate=200.0, n_requests=24, mix="mixed",
                                seed=3, vocab=cfg.vocab_size)
    cm = serve_compute_model(cfg, flops_per_sec=1e9)
    cont = replay(traffic_engine(cfg, params, spec, 4), spec, cm)
    sync = replay_seed_sync(spec, cm, batch=4)
    assert cont.summary["tok_per_sec"] > sync.summary["tok_per_sec"]
    assert cont.summary["p50_ttft_s"] < sync.summary["p50_ttft_s"]
