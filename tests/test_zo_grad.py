"""ZO estimator correctness: finite-difference accuracy + estimator geometry."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import directions as D
from repro.core.zo_grad import reconstruct_update, zo_coefficient, zo_gradient


def quad_loss(params, batch):
    return 0.5 * jnp.sum((params["x"] - batch["c"]) ** 2)


def test_coefficient_matches_directional_derivative():
    """c/d == <grad f, v> + (mu/2)||v||^2 exactly for a quadratic.

    (mu can't be tiny in float32: f ~ 20 has ~2e-6 resolution, so a 1e-5
    finite difference would be pure cancellation noise.)
    """
    d = 128
    mu = 1e-2
    params = {"x": jnp.linspace(-1, 1, d)}
    batch = {"c": jnp.zeros((d,))}
    v = D.sphere_direction(params, 0, jnp.int32(0), jnp.uint32(0))
    c, f0 = zo_coefficient(quad_loss, params, batch, v, mu=mu, dim=d)
    grad = jax.grad(quad_loss)(params, batch)
    # quadratic: F(x+mu v)-F(x) = mu <g,v> + mu^2/2 ||v||^2, ||v|| = 1
    expected = d * (float(jnp.sum(grad["x"] * v["x"])) + mu / 2)
    assert abs(float(c) - expected) < 0.05 * max(1.0, abs(expected)), (
        float(c), expected)
    assert abs(float(f0) - float(quad_loss(params, batch))) < 1e-6


def test_coefficient_error_shrinks_with_mu():
    """Smoothing bias is O(mu): halving mu halves the quadratic term."""
    d = 64
    params = {"x": jnp.linspace(-1, 1, d)}
    batch = {"c": jnp.zeros((d,))}
    v = D.sphere_direction(params, 0, jnp.int32(1), jnp.uint32(0))
    grad = jax.grad(quad_loss)(params, batch)
    lin = d * float(jnp.sum(grad["x"] * v["x"]))
    errs = []
    for mu in (4e-2, 2e-2):
        c, _ = zo_coefficient(quad_loss, params, batch, v, mu=mu, dim=d)
        errs.append(abs(float(c) - lin))
    assert errs[1] < 0.7 * errs[0], errs


def test_zo_gradient_positively_correlated():
    """Averaged over M sphere directions the ZO estimate aligns with the true
    gradient with cos ~= sqrt(M/(M+d)) (random-projection geometry)."""
    d = 256
    params = {"x": jnp.linspace(-2, 2, d)}
    batch = {"c": jnp.ones((d,))}
    true_g = jax.grad(quad_loss)(params, batch)["x"]
    acc = jnp.zeros((d,))
    M = 128
    for i in range(M):
        g, _, _ = zo_gradient(quad_loss, params, batch, 0, jnp.int32(0),
                              jnp.uint32(i), mu=1e-3)
        acc = acc + g["x"]
    est = acc / M
    cos = float(jnp.dot(est, true_g) /
                (jnp.linalg.norm(est) * jnp.linalg.norm(true_g)))
    expect = (M / (M + d)) ** 0.5           # ~0.577 for M=128, d=256
    assert cos > 0.6 * expect, (cos, expect)


def test_reconstruct_equals_sum_of_worker_grads():
    d = 64
    params = {"x": jnp.linspace(0, 1, d)}
    batch = {"c": jnp.zeros((d,))}
    m, mu = 4, 1e-4
    coeffs = []
    total = jnp.zeros((d,))
    for i in range(m):
        g, c, _ = zo_gradient(quad_loss, params, batch, 0, jnp.int32(2),
                              jnp.uint32(i), mu)
        coeffs.append(c)
        total = total + g["x"]
    rec = reconstruct_update(params, jnp.stack(coeffs), 0, jnp.int32(2))
    np.testing.assert_allclose(np.asarray(rec["x"]), np.asarray(total / m),
                               rtol=1e-5, atol=1e-6)
