"""HO-SGD algorithm semantics (Algorithm 1, §3.3 spectrum claims)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    HOSGDConfig, make_ho_sgd, make_sync_sgd, make_zo_sgd, run_method,
)


def quad_loss(params, batch):
    return 0.5 * jnp.mean(jnp.sum((params["x"] - batch["t"]) ** 2, -1))


def quad_batches(m, B, d, noise=0.1, seed=0):
    rng = np.random.default_rng(seed)
    while True:
        yield {"t": (1.0 + noise * rng.normal(size=(m * B, d))).astype(np.float32)}


D_ = 64
P0 = {"x": jnp.zeros((D_,))}


def final_gap(hist):
    return float(quad_loss(hist["params"], {"t": np.ones((1, D_), np.float32)}))


def test_tau1_equals_sync_sgd_trajectory():
    """§3.3: tau=1 reduces to fully synchronous SGD — identical trajectories."""
    m, B = 4, 8
    ho = make_ho_sgd(quad_loss, HOSGDConfig(tau=1, m=m, lr=0.3))
    sync = make_sync_sgd(quad_loss, m, lr=0.3)
    h1 = run_method(ho, P0, quad_batches(m, B, D_), 20)
    h2 = run_method(sync, P0, quad_batches(m, B, D_), 20)
    np.testing.assert_allclose(np.asarray(h1["params"]["x"]),
                               np.asarray(h2["params"]["x"]), rtol=1e-6)
    assert all(o == 1 for o in h1["order"])


def test_order_schedule():
    m, B, tau = 4, 4, 5
    meth = make_ho_sgd(quad_loss, HOSGDConfig(tau=tau, m=m, lr=0.05,
                                              zo_lr=0.05 / D_))
    hist = run_method(meth, P0, quad_batches(m, B, D_), 12)
    assert hist["order"] == [1 if t % tau == 0 else 0 for t in range(12)]


def test_hybrid_converges():
    m, B = 4, 8
    meth = make_ho_sgd(quad_loss, HOSGDConfig(tau=8, m=m, lr=0.3, zo_lr=0.3 / 8,
                                              mu=1e-4))
    hist = run_method(meth, P0, quad_batches(m, B, D_), 200)
    assert final_gap(hist) < 0.05, final_gap(hist)


def test_zo_only_converges_slower_than_hybrid():
    """Order comparison on equal footing: same lr on ZO steps."""
    m, B, iters = 4, 8, 160
    zo = make_zo_sgd(quad_loss, m, mu=1e-4, lr=0.3 / 8)
    hy = make_ho_sgd(quad_loss, HOSGDConfig(tau=8, m=m, lr=0.3, zo_lr=0.3 / 8,
                                            mu=1e-4))
    g_zo = final_gap(run_method(zo, P0, quad_batches(m, B, D_), iters))
    g_hy = final_gap(run_method(hy, P0, quad_batches(m, B, D_), iters))
    assert g_hy < g_zo, (g_hy, g_zo)


def test_cost_model_table1():
    """Per-iteration comm/compute counters match Table 1 formulas."""
    d = 10_000
    hy = make_ho_sgd(quad_loss, HOSGDConfig(tau=8, m=4, lr=0.1))
    assert hy.comm_scalars(d) == pytest.approx((8 - 1 + d) / 8)
    assert hy.fevals(d) == pytest.approx(2 * 7 / 8)
    assert hy.gevals(d) == pytest.approx(1 / 8)
    sync = make_sync_sgd(quad_loss, 4, lr=0.1)
    assert sync.comm_scalars(d) == d and sync.gevals(d) == 1.0
    zo = make_zo_sgd(quad_loss, 4, mu=1e-3, lr=0.1)
    assert zo.comm_scalars(d) == 1.0 and zo.fevals(d) == 2.0


def test_adaptive_tau_counter_lives_in_state():
    """The since-FO counter is method *state*: re-initialization restarts the
    schedule, and two interleaved runs can't contaminate each other (the old
    mutable-closure counter leaked across run_method calls)."""
    from repro.core.ho_sgd import make_adaptive_ho_sgd
    meth = make_adaptive_ho_sgd(
        quad_loss, HOSGDConfig(tau=8, m=4, lr=0.05, zo_lr=0.05 / D_),
        tau_schedule=lambda t: 3)
    m, B = 4, 4

    def orders(state, ts):
        params, out = P0, []
        for t in ts:
            batch = next(quad_batches(m, B, D_, seed=t))
            params, state, metrics = meth.step(t, params, state, batch)
            out.append(int(metrics["order"]))
        return state, out

    # two independent states stepped in lockstep see identical schedules
    sa, sb = meth.init(P0), meth.init(P0)
    sa, oa = orders(sa, range(7))
    sb, ob = orders(sb, range(7))
    assert oa == ob == [1, 0, 0, 1, 0, 0, 1]
    # a run that stopped mid-period doesn't leak its position into a fresh init
    _, o_fresh = orders(meth.init(P0), range(7))
    assert o_fresh == oa


def test_zo_step_uses_two_fevals_per_worker():
    """Count actual loss_fn invocations in a traced ZO step."""
    calls = {"n": 0}

    def counting_loss(params, batch):
        calls["n"] += 1
        return quad_loss(params, batch)

    m = 3
    meth = make_ho_sgd(counting_loss, HOSGDConfig(tau=1 << 30, m=m, lr=1e-3))
    state = meth.init(P0)
    batch = next(quad_batches(m, 2, D_))
    meth.step(1, P0, state, batch)  # traces once: 2 evals per worker
    assert calls["n"] == 2 * m
