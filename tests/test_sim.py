"""repro.sim: event-loop determinism, cluster scenarios, and the paper's
qualitative Table-1 ordering on simulated wall-clock."""
import math

import jax
import jax.numpy as jnp
import pytest

from repro.sim import (
    ClusterSpec,
    ComputeModel,
    EventLoop,
    WorkerClocks,
    barrier_all_reduce,
    compute_model_for,
    make_sim_methods,
    simulate,
)
from repro.sim.costs import LinkModel, StepCost, validate_against_method


def quad_loss(params, batch):
    return 0.5 * jnp.mean(jnp.sum((params["x"] - batch["t"]) ** 2, -1))


QUAD_D, QUAD_M = 64, 4


def quad_problem():
    params = {"x": jnp.zeros((QUAD_D,), jnp.float32)}
    batch = {"t": jnp.ones((2 * QUAD_M, QUAD_D), jnp.float32)}
    return params, batch


def quad_batches(batch):
    while True:
        yield batch


def run_quad(cluster, *, which="ho_sgd", n_iters=12, tau=4, zo_lr=0.05,
             target_loss=None, **sim_kw):
    params, batch = quad_problem()
    sm = make_sim_methods(quad_loss, params, cluster, tau=tau, lr=0.1,
                          zo_lr=zo_lr, which=[which])[which]
    compute = compute_model_for(params, cluster, 2)
    return simulate(sm, params, quad_batches(batch), cluster, n_iters,
                    compute=compute, target_loss=target_loss, **sim_kw)


# --------------------------------------------------------------------------- #
# events: the determinism core
# --------------------------------------------------------------------------- #
def test_event_loop_fifo_tiebreak():
    loop = EventLoop()
    for w in (3, 1, 2):            # same time: pop order = scheduling order
        loop.schedule(1.0, "compute", w)
    loop.schedule(0.5, "compute", 9)
    assert [loop.pop().worker for _ in range(4)] == [9, 3, 1, 2]
    assert loop.now == 1.0
    assert [e[0] for e in loop.trace] == [0.5, 1.0, 1.0, 1.0]


def test_barrier_all_reduce_semantics():
    loop, clocks = EventLoop(), WorkerClocks.start(3, at=1.0)
    link = LinkModel(alpha=0.5, beta=0.125)
    done = barrier_all_reduce(loop, clocks, [0.1, 0.7, 0.3],
                              link.time(8))    # 0.5 + 8*0.125 = 1.5
    assert done == pytest.approx(1.0 + 0.7 + 1.5)
    assert clocks.t == [done] * 3
    kinds = [k for _, k, _ in loop.trace]
    assert kinds == ["compute"] * 3 + ["all_reduce"]
    # compute events drained in global time order, not worker order
    assert [w for _, k, w in loop.trace if k == "compute"] == [0, 2, 1]


def test_barrier_without_exchange_records_barrier():
    loop, clocks = EventLoop(), WorkerClocks.start(2)
    done = barrier_all_reduce(loop, clocks, [0.2, 0.1], 0.0)
    assert done == pytest.approx(0.2)
    assert loop.trace[-1][1] == "barrier"


# --------------------------------------------------------------------------- #
# cluster scenarios
# --------------------------------------------------------------------------- #
def test_same_seed_identical_trace():
    """The determinism contract: same ClusterSpec seed => same event trace."""
    spec = ClusterSpec(m=QUAD_M, flops_per_sec=1e9, bandwidth=1e6,
                       straggler_prob=0.3, jitter_sigma=0.2, seed=7)
    r1 = run_quad(spec)
    r2 = run_quad(spec)
    assert r1.trace == r2.trace
    assert r1.times == r2.times and r1.losses == r2.losses
    r3 = run_quad(spec.with_(seed=8))
    assert r3.trace != r1.trace


def test_stragglers_stretch_the_critical_path():
    base = ClusterSpec(m=QUAD_M, flops_per_sec=1e9, bandwidth=1e6, seed=0)
    slow = base.with_(straggler_prob=1.0, straggler_slowdown=5.0)
    r_base, r_slow = run_quad(base), run_quad(slow)
    assert r_slow.compute_s == pytest.approx(5.0 * r_base.compute_s)
    assert r_slow.comm_s == pytest.approx(r_base.comm_s)  # links unaffected
    assert r_slow.sim_seconds > r_base.sim_seconds


def test_heterogeneous_speeds_slow_worker_dominates():
    base = ClusterSpec(m=QUAD_M, flops_per_sec=1e9, bandwidth=1e6, seed=0)
    hetero = base.with_(rel_speeds=(1.0, 1.0, 1.0, 0.25))
    r_base, r_het = run_quad(base), run_quad(hetero)
    # the barrier waits for the 4x-slower worker every iteration
    assert r_het.compute_s == pytest.approx(4.0 * r_base.compute_s)


def test_failure_injection_restores_from_checkpoint(tmp_path):
    # iteration duration here is ~2.6e-4 sim seconds (256-byte FO exchange
    # at 1e6 B/s), so a rate of 1000/s yields a few failures over 10 iters
    spec = ClusterSpec(m=QUAD_M, flops_per_sec=1e9, bandwidth=1e6,
                       fail_rate=1000.0, restart_time=0.01, ckpt_every=2,
                       seed=3)
    res = run_quad(spec, n_iters=10, ckpt_dir=str(tmp_path))
    assert res.failures > 0
    kinds = [k for _, k, _ in res.trace]
    assert "fail" in kinds and "restore" in kinds
    # committed trace stays monotone in simulated time
    times = [t for t, _, _ in res.trace]
    assert times == sorted(times)
    # every iteration up to n_iters eventually committed despite rollbacks
    assert res.steps[-1] == 9
    # the failure-free run of the same method reaches the same final params
    # (restore is a REAL repro.checkpoint round-trip, so state survives)
    ref = run_quad(spec.with_(fail_rate=0.0, ckpt_every=0), n_iters=10)
    for a, b in zip(jax.tree.leaves(res.params), jax.tree.leaves(ref.params)):
        assert jnp.array_equal(a, b)


def test_failure_restore_ignores_stale_checkpoints(tmp_path):
    """A caller-supplied ckpt_dir may hold other runs' checkpoints; failure
    recovery must restore the step THIS run saved, not the global latest."""
    from repro.checkpoint import save as ckpt_save

    params, _ = quad_problem()
    ckpt_save(str(tmp_path), 99, {            # stale foreign checkpoint
        "params": jax.tree.map(lambda x: x + 100.0, params),
        "state": {"opt": (), "since_fo": 0}})
    spec = ClusterSpec(m=QUAD_M, flops_per_sec=1e9, bandwidth=1e6,
                       fail_rate=1000.0, restart_time=0.01, ckpt_every=2,
                       seed=3)
    res = run_quad(spec, n_iters=10, ckpt_dir=str(tmp_path))
    assert res.failures > 0
    ref = run_quad(spec.with_(fail_rate=0.0, ckpt_every=0), n_iters=10)
    for a, b in zip(jax.tree.leaves(res.params), jax.tree.leaves(ref.params)):
        assert jnp.array_equal(a, b)


def test_failed_iterations_are_rerun_not_skipped():
    spec = ClusterSpec(m=QUAD_M, flops_per_sec=1e9, bandwidth=1e6,
                       fail_rate=5000.0, restart_time=0.01, ckpt_every=3,
                       seed=5)
    res = run_quad(spec, n_iters=8)
    assert res.failures > 0
    # rollbacks re-run lost iterations; every index still commits eventually
    assert sorted(set(res.steps)) == list(range(8))
    assert res.steps[-1] == 7


# --------------------------------------------------------------------------- #
# cost model cross-checks
# --------------------------------------------------------------------------- #
def test_compute_model_prices_fo_vs_zo():
    cm = ComputeModel(fwd_flops=1e6, flops_per_sec=1e9, fwd_bwd_ratio=3.0)
    assert cm.time(2.0, 0.0) == pytest.approx(2e-3)     # ZO: two fevals
    assert cm.time(0.0, 1.0) == pytest.approx(3e-3)     # FO: fwd+bwd
    assert cm.time(0.0, 1.0, speed=2.0) == pytest.approx(1.5e-3)


def test_per_order_costs_match_method_analytics():
    """The runner's per-order eval counts amortize to Method.fevals/gevals."""
    from repro.core import HOSGDConfig, make_ho_sgd

    tau = 4
    meth = make_ho_sgd(quad_loss, HOSGDConfig(tau=tau, m=QUAD_M, lr=0.1))
    costs = {1: StepCost(0.0, 1.0, 0), 0: StepCost(2.0, 0.0, 0)}
    mix = {1: 1.0 / tau, 0: (tau - 1.0) / tau}
    validate_against_method(meth, QUAD_D, costs, mix)


def test_sim_bytes_come_from_the_ledger():
    """HO iterations are priced at exactly the bytes their programs booked."""
    spec = ClusterSpec(m=QUAD_M, flops_per_sec=1e9, bandwidth=1e6, seed=0)
    res = run_quad(spec, n_iters=8, tau=4)
    # 2 FO steps book 4*d each; 6 ZO steps book 4*m each (m in-program)
    assert res.bytes_total == 2 * 4 * QUAD_D + 6 * 4 * QUAD_M


def test_zo_comm_independent_of_d_in_sim():
    spec = ClusterSpec(m=QUAD_M, flops_per_sec=1e9, bandwidth=1e6, seed=0)
    res = run_quad(spec, which="zo_sgd", n_iters=6)
    assert all(o == 0 for o in res.orders)
    assert res.bytes_total == 6 * 4 * QUAD_M


# --------------------------------------------------------------------------- #
# the acceptance ordering (paper Table 1 on simulated wall-clock)
# --------------------------------------------------------------------------- #
def test_table1_ordering_on_simulated_wallclock():
    """Bandwidth-constrained cluster: HO-SGD hits the target loss in fewer
    simulated seconds than sync-SGD, and in fewer function-evaluation
    seconds than ZO-only SGD."""
    from repro.data.synthetic import batches, make_classification
    from repro.models.mlp import init_mlp_classifier, mlp_loss

    ds = make_classification("acoustic", n_train=2048, n_test=512, seed=0)
    params = init_mlp_classifier(jax.random.key(0), ds.n_features,
                                 ds.n_classes, hidden=32)
    cluster = ClusterSpec(m=4, flops_per_sec=1e9, alpha=1e-5, bandwidth=1e5,
                          seed=0)
    compute = compute_model_for(params, cluster, 16)
    eval_batch = {"x": ds.x_test, "y": ds.y_test}
    eval_fn = jax.jit(lambda p: mlp_loss(p, eval_batch))
    target = 0.75

    sims = make_sim_methods(mlp_loss, params, cluster, tau=8, lr=0.05,
                            zo_lr=0.002,
                            which=["ho_sgd", "sync_sgd", "zo_sgd"])
    out = {}
    for name, sm in sims.items():
        out[name] = simulate(sm, params, batches(ds, 64, seed=0), cluster,
                             500, compute=compute, eval_fn=eval_fn,
                             eval_every=1, target_loss=target)
    t_ho = out["ho_sgd"].time_to_loss(target)
    t_sync = out["sync_sgd"].time_to_loss(target)
    fs_ho = out["ho_sgd"].feval_seconds_to_loss(target)
    fs_zo = out["zo_sgd"].feval_seconds_to_loss(target)
    assert math.isfinite(t_ho) and math.isfinite(t_sync)
    assert t_ho < t_sync, f"HO {t_ho} !< sync {t_sync} (simulated seconds)"
    assert math.isfinite(fs_zo)
    assert fs_ho < fs_zo, f"HO {fs_ho} !< ZO {fs_zo} (feval seconds)"
    # sync still wins on iteration count — the tradeoff, not a free lunch
    assert len(out["sync_sgd"].steps) <= len(out["ho_sgd"].steps)
