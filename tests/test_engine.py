"""DirectionEngine backend equivalence: tree == fused == pallas == flat.

The engine contract (README §DirectionEngine) promises the backends
evaluate the *identical* algebra: same hashed gaussians, same fp32
elementwise expressions, same per-worker acc_dtype rounding.  With tiles
covering whole leaves the outputs are bit-identical; with sub-leaf tiles
XLA's shape-dependent transcendental vectorization can move the last ulp,
so the tiled assertions allow a few-ulp tolerance.

The ``flat`` backend additionally ships a fused single-buffer step path
(perturb+sumsq in one launch, reconstruct+SGD commit in one launch) whose
kernel-side sumsq has a different reduction order than the shared jnp one —
that path is pinned loss-equivalent (rtol) to the ``fused`` engine rather
than bitwise, with donation safety and the non-SGD fallback pinned here too.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import directions as D
from repro.core.engine import ENGINES, make_engine
from repro.core.ho_sgd import HOSGDConfig, make_ho_sgd, run_method

KEY = jax.random.key(0)
SEED, T = 3, jnp.int32(5)

# odd leaf sizes on purpose: none is a multiple of the pallas block below,
# and the scalar leaf exercises the degenerate (1,)-flat kernel path
SHAPE_SETS = [
    {"w": (37, 3), "b": (129,), "s": ()},
    {"a": (1000,), "c": (261,)},
]
WHOLE_LEAF_BLOCK = 4096   # >= every leaf above: bitwise regime
TILED_BLOCK = 64          # tail blocks everywhere: few-ulp regime


def _params(shapes, dtype):
    return {
        k: (jax.random.normal(jax.random.fold_in(KEY, i), s, jnp.float32)
            .astype(dtype))
        for i, (k, s) in enumerate(sorted(shapes.items()))
    }


def _engines(params, acc_dtype="float32", block=WHOLE_LEAF_BLOCK):
    return {
        name: make_engine(name, params, SEED, acc_dtype=acc_dtype, block=block)
        for name in ENGINES
    }


def _leaves32(tree):
    return [np.asarray(x, np.float32) for x in jax.tree.leaves(tree)]


@pytest.mark.parametrize("shapes", SHAPE_SETS)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_inv_norm_identical_and_matches_directions(shapes, dtype):
    params = _params(shapes, dtype)
    engines = _engines(params)
    w = jnp.uint32(2)
    invs = {n: float(jax.jit(e.inv_norm)(T, w)) for n, e in engines.items()}
    assert len(set(invs.values())) == 1, invs
    v = D.raw_direction(params, SEED, T, w)
    ssq = sum(jnp.sum(jnp.square(x)) for x in jax.tree.leaves(v))
    assert invs["tree"] == pytest.approx(float(jax.lax.rsqrt(ssq + 1e-30)),
                                         rel=1e-6)


@pytest.mark.parametrize("shapes", SHAPE_SETS)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_perturb_bit_identical_across_backends(shapes, dtype):
    params = _params(shapes, dtype)
    engines = _engines(params)
    w = jnp.uint32(1)
    scale = jnp.float32(1e-2) * engines["tree"].inv_norm(T, w)
    outs = {
        n: jax.jit(lambda p, e=e: e.perturb(p, T, w, scale))(params)
        for n, e in engines.items()
    }
    for n in ("fused", "pallas", "flat"):
        for a, b in zip(_leaves32(outs["tree"]), _leaves32(outs[n])):
            np.testing.assert_array_equal(a, b, err_msg=n)
    # and it actually perturbs: every (non-scalar) leaf moved
    for p0, p1 in zip(_leaves32(params), _leaves32(outs["tree"])):
        if p0.size > 1:
            assert np.any(p0 != p1)


@pytest.mark.parametrize("shapes", SHAPE_SETS)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_zo_coeff_bit_identical_across_backends(shapes, dtype):
    params = _params(shapes, dtype)
    target = jax.tree.map(lambda x: jnp.zeros_like(x), params)

    def loss_fn(p, b):
        return sum(
            0.5 * jnp.sum(jnp.square(x.astype(jnp.float32) - t.astype(jnp.float32)))
            for x, t in zip(jax.tree.leaves(p), jax.tree.leaves(b)))

    outs = {}
    for n, e in _engines(params).items():
        c, f0 = jax.jit(
            lambda p, b, e=e: e.zo_coeff(loss_fn, p, b, T, jnp.uint32(0), 1e-2)
        )(params, target)
        outs[n] = (float(c), float(f0))
    assert outs["tree"] == outs["fused"] == outs["pallas"] == outs["flat"], outs


@pytest.mark.parametrize("shapes", SHAPE_SETS)
@pytest.mark.parametrize("acc_dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_reconstruct_equivalent_across_backends(shapes, dtype, acc_dtype):
    """Same algebra, same per-worker acc_dtype rounding, in every backend.

    With a sub-fp32 accumulator the rounding absorbs XLA's FMA-contraction
    freedom and the three backends are bit-identical.  With an fp32
    accumulator the chained multiply-adds may or may not be contracted to
    fma depending on the surrounding program (unrolled vs fori_loop vs
    kernel), so equality is to a couple of ulps — the only non-bitwise
    seam in the contract, and inherent to XLA, not to the backends.
    """
    params = _params(shapes, dtype)
    engines = _engines(params, acc_dtype=acc_dtype)
    cs = jnp.asarray([0.5, -1.0, 2.0, 0.1], jnp.float32)
    recs = {n: jax.jit(lambda e=e: e.reconstruct(cs, T))()
            for n, e in engines.items()}
    for n in ("fused", "pallas", "flat"):
        for a, b in zip(_leaves32(recs["tree"]), _leaves32(recs[n])):
            if acc_dtype == "bfloat16":
                np.testing.assert_array_equal(a, b, err_msg=f"{n} acc={acc_dtype}")
            else:
                np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-8,
                                           err_msg=f"{n} acc={acc_dtype}")


@pytest.mark.parametrize("backend", ["pallas", "flat"])
@pytest.mark.parametrize("shapes", SHAPE_SETS)
def test_tiled_kernel_backends_match_within_ulps(shapes, backend):
    """Sub-leaf tiles (tail-masked blocks) may differ from the whole-leaf
    evaluation only by XLA's shape-dependent transcendental rounding."""
    params = _params(shapes, jnp.float32)
    whole = make_engine(backend, params, SEED, block=WHOLE_LEAF_BLOCK)
    tiled = make_engine(backend, params, SEED, block=TILED_BLOCK)
    w = jnp.uint32(1)
    scale = jnp.float32(1e-2) * whole.inv_norm(T, w)
    a = jax.jit(lambda p: whole.perturb(p, T, w, scale))(params)
    b = jax.jit(lambda p: tiled.perturb(p, T, w, scale))(params)
    for x, y in zip(_leaves32(a), _leaves32(b)):
        np.testing.assert_allclose(x, y, rtol=1e-6, atol=1e-6)
    cs = jnp.asarray([0.5, -1.0, 2.0, 0.1], jnp.float32)
    a = jax.jit(lambda: whole.reconstruct(cs, T))()
    b = jax.jit(lambda: tiled.reconstruct(cs, T))()
    for x, y in zip(_leaves32(a), _leaves32(b)):
        np.testing.assert_allclose(x, y, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("backend", ["tree", "fused", "flat"])
def test_vmapped_vs_unrolled_reconstruct(backend):
    params = _params(SHAPE_SETS[0], jnp.float32)
    eng = make_engine(backend, params, SEED)
    cs = jnp.asarray([0.5, -1.0, 2.0, 0.1], jnp.float32)
    seq = jax.jit(lambda: eng.reconstruct(cs, T))()
    vm = jax.jit(lambda: eng.reconstruct(cs, T, vmap_workers=True))()
    for a, b in zip(_leaves32(seq), _leaves32(vm)):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_vmapped_reconstruct_hlo_o1_in_m():
    """The vmapped-worker variant's program size must not grow with m
    (the ROADMAP large-m CPU-rehearsal item); the unrolled tree path does."""
    params = _params(SHAPE_SETS[0], jnp.float32)
    eng = make_engine("tree", params, SEED)

    def size(m, vmap_workers):
        cs = jnp.zeros((m,), jnp.float32)
        return len(
            jax.jit(lambda c: eng.reconstruct(c, T, vmap_workers=vmap_workers))
            .lower(cs).as_text())

    assert size(16, True) < 1.15 * size(4, True)
    assert size(16, False) > 2.0 * size(4, False)  # the unrolled contrast


def test_engine_metadata_offsets():
    params = _params(SHAPE_SETS[0], jnp.float32)
    eng = make_engine("tree", params, SEED)
    assert eng.dim == sum(eng.sizes) == D.tree_dim(params)
    np.testing.assert_array_equal(eng.offsets,
                                  np.cumsum([0] + eng.sizes[:-1]))


@pytest.mark.parametrize("engine", ["tree", "fused", "pallas"])
def test_hot_path_zo_steps_identical_across_engines(engine):
    """make_ho_sgd's jitted ZO step produces the same trajectory on every
    backend (the backends see identical losses, coefficients, updates)."""

    def quad_loss(p, b):
        return 0.5 * jnp.mean(jnp.sum((p["x"] - b["t"]) ** 2, -1))

    m, B, d = 4, 4, 63                     # odd d: pallas tail block
    p0 = {"x": jnp.zeros((d,))}

    def batches():
        rng = np.random.default_rng(0)
        while True:
            yield {"t": (1.0 + 0.1 * rng.normal(size=(m * B, d))).astype(np.float32)}

    hists = {}
    for name in ("tree", engine):
        # bf16 accumulator: per-worker rounding absorbs FMA-contraction
        # freedom, so whole trajectories are bit-identical across backends
        cfg = HOSGDConfig(tau=1 << 30, mu=1e-3, m=m, lr=0.1, zo_lr=0.1 / d,
                          engine=name, acc_dtype="bfloat16")
        hists[name] = run_method(make_ho_sgd(quad_loss, cfg), p0, batches(), 5)
    np.testing.assert_array_equal(
        np.asarray(hists["tree"]["params"]["x"]),
        np.asarray(hists[engine]["params"]["x"]))
    assert hists["tree"]["loss"] == hists[engine]["loss"]


def test_zo_step_engines_agree_on_1x1_mesh():
    """distributed make_zo_step (auto fallback) agrees across backends."""
    from repro import compat
    from repro.core.distributed import make_zo_step
    from repro.launch.mesh import make_test_mesh
    from repro.opt.optimizers import const_schedule, sgd

    def loss_fn(p, b):
        return 0.5 * jnp.mean(jnp.sum((p["x"] - b["t"]) ** 2, -1))

    d = 130
    params = {"x": jnp.linspace(-1.0, 1.0, d)}
    batch = {"t": jnp.ones((4, d), jnp.float32)}
    mesh = make_test_mesh(data=1, model=1)
    outs = {}
    with compat.set_mesh(mesh):
        for name in ("tree", "fused", "pallas"):
            ho = HOSGDConfig(tau=1 << 30, mu=1e-3, m=2, lr=0.05,
                             zo_lr=0.05 / d, engine=name,
                             acc_dtype="bfloat16")
            opt = sgd(const_schedule(ho.lr))
            zo = jax.jit(make_zo_step(loss_fn, mesh, ho, opt, m=2))
            p1, _, loss = zo(jnp.int32(3), params, opt.init(params), batch)
            outs[name] = (np.asarray(p1["x"]), float(loss))
    np.testing.assert_array_equal(outs["tree"][0], outs["fused"][0])
    np.testing.assert_array_equal(outs["tree"][0], outs["pallas"][0])
    assert outs["tree"][1] == outs["fused"][1] == outs["pallas"][1]


def test_zo_step_vmap_workers_fallback_close():
    """The O(1)-in-m vmapped fallback matches the unrolled one (vmap batches
    the loss evals, so equality is to fp tolerance, not bitwise)."""
    from repro import compat
    from repro.core.distributed import make_zo_step
    from repro.launch.mesh import make_test_mesh
    from repro.opt.optimizers import const_schedule, sgd

    def loss_fn(p, b):
        return 0.5 * jnp.mean(jnp.sum((p["x"] - b["t"]) ** 2, -1))

    d = 96
    params = {"x": jnp.linspace(-1.0, 1.0, d)}
    batch = {"t": jnp.ones((8, d), jnp.float32)}
    mesh = make_test_mesh(data=1, model=1)
    outs = {}
    with compat.set_mesh(mesh):
        for vw in (False, True):
            ho = HOSGDConfig(tau=1 << 30, mu=1e-2, m=4, lr=0.05,
                             zo_lr=0.05 / d)
            opt = sgd(const_schedule(ho.lr))
            zo = jax.jit(make_zo_step(loss_fn, mesh, ho, opt, m=4,
                                      vmap_workers=vw))
            p1, _, loss = zo(jnp.int32(3), params, opt.init(params), batch)
            outs[vw] = (np.asarray(p1["x"]), float(loss))
    np.testing.assert_allclose(outs[True][0], outs[False][0],
                               rtol=1e-4, atol=1e-6)
    assert outs[True][1] == pytest.approx(outs[False][1], rel=1e-5)


@pytest.mark.parametrize("engine", ["fused", "pallas"])
def test_zo_step_memory_o_params_independent_of_m(engine):
    """No materialized full-leaf direction buffer: the compiled ZO step's
    temp memory is O(params) — flat in m (ISSUE 2 acceptance criterion)."""
    from repro import compat
    from repro.core.distributed import make_zo_step
    from repro.launch.hlo import memory_summary
    from repro.launch.mesh import make_test_mesh
    from repro.opt.optimizers import const_schedule, sgd

    def loss_fn(p, b):
        return 0.5 * jnp.mean(jnp.sum((p["x"] - b["t"]) ** 2, -1))

    d = 1 << 16
    params = {"x": jnp.zeros((d,))}
    mesh = make_test_mesh(data=1, model=1)
    temps = {}
    with compat.set_mesh(mesh):
        for m in (2, 8):
            batch = {"t": jnp.ones((m, d), jnp.float32)}
            ho = HOSGDConfig(tau=1 << 30, mu=1e-3, m=m, lr=0.05, zo_lr=1e-6,
                             engine=engine)
            opt = sgd(const_schedule(ho.lr))
            zo = jax.jit(make_zo_step(loss_fn, mesh, ho, opt, m=m))
            comp = zo.lower(jnp.int32(1), params, opt.init(params),
                            batch).compile()
            temps[m] = memory_summary(comp).get("temp_size_in_bytes")
    if temps[2] is None:
        pytest.skip("memory_analysis unavailable on this backend")
    # flat in m, and params-order overall (a few live d-vectors, not m of
    # them; 6*4*d leaves headroom for backend/XLA scheduling variation)
    assert temps[8] <= 1.2 * temps[2], temps
    assert temps[8] <= 6 * 4 * d, temps


def test_unknown_engine_raises():
    with pytest.raises(ValueError, match="unknown direction engine"):
        make_engine("mosaic", {"x": jnp.zeros((3,))}, 0)


# --------------------------------------------------------------------------- #
# flat backend: packed buffer + fused single-buffer step path                  #
# --------------------------------------------------------------------------- #

def _quad_loss(p, b):
    return 0.5 * jnp.mean(jnp.sum((p["x"] - b["t"]) ** 2, -1))


def _quad_batches(m, B, d, seed=0):
    rng = np.random.default_rng(seed)
    while True:
        yield {"t": (1.0 + 0.1 * rng.normal(size=(m * B, d))).astype(np.float32)}


def test_flat_pack_unpack_roundtrip():
    """pack/unpack is lossless through the block-padded fp32 buffer —
    including scalar leaves and bf16 leaves (bf16 -> f32 -> bf16 is exact)."""
    params = {
        "w": jax.random.normal(KEY, (37, 3), jnp.float32),
        "b": jnp.linspace(-1.0, 1.0, 129).astype(jnp.bfloat16),
        "s": jnp.asarray(0.25, jnp.float32),
    }
    eng = make_engine("flat", params, SEED)
    buf = eng.pack(params)
    assert buf.dtype == jnp.float32 and buf.shape == (eng.padded_dim,)
    assert eng.padded_dim % eng.block == 0
    out = eng.unpack(buf)
    assert jax.tree.structure(out) == jax.tree.structure(params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(out)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    # cast=False keeps fp32 leaves (update / momentum trees)
    for x in jax.tree.leaves(eng.unpack(buf, cast=False)):
        assert x.dtype == jnp.float32


@pytest.mark.parametrize("momentum", [0.0, 0.9])
def test_flat_fused_step_loss_equivalent_to_fused(momentum):
    """ISSUE 10 acceptance: --engine flat is pinned loss-equivalent (rtol)
    to --engine fused on a toy problem, with and without momentum.  Not
    bitwise: the fused path consumes the kernel's blockwise sumsq, whose
    reduction order differs from the shared jnp inv-norm."""
    m, B, d = 4, 4, 63
    p0 = {"x": jnp.zeros((d,))}
    hists = {}
    for name in ("fused", "flat"):
        cfg = HOSGDConfig(tau=1 << 30, mu=1e-3, m=m, lr=0.1, zo_lr=0.1 / d,
                          engine=name, momentum=momentum)
        hists[name] = run_method(make_ho_sgd(_quad_loss, cfg), p0,
                                 _quad_batches(m, B, d), 12)
    np.testing.assert_allclose(hists["flat"]["loss"], hists["fused"]["loss"],
                               rtol=1e-4)
    # the params pin is looser than the loss pin: the ulp-level sumsq
    # difference enters each step scaled by (d/mu)*(f1-f0) and compounds
    np.testing.assert_allclose(np.asarray(hists["flat"]["params"]["x"]),
                               np.asarray(hists["fused"]["params"]["x"]),
                               rtol=5e-3, atol=1e-5)


def test_flat_fused_step_donation_safe():
    """The fused commit kernel donates its packed buffers; the jitted step
    must still leave the caller's params/opt_state usable (the donation is
    of the *packed copy*, never of caller-visible arrays)."""
    m, B, d = 2, 2, 37
    p0 = {"x": jnp.linspace(-1.0, 1.0, d)}
    cfg = HOSGDConfig(tau=1 << 30, mu=1e-3, m=m, lr=0.1, zo_lr=0.1 / d,
                      engine="flat", momentum=0.9)
    meth = make_ho_sgd(_quad_loss, cfg)
    state = meth.init(p0)
    batch = next(_quad_batches(m, B, d))
    p1, s1, met1 = meth.step(1, p0, state, batch)
    # same arrays again: donation must not have consumed them
    assert not p0["x"].is_deleted()
    p2, s2, met2 = meth.step(1, p0, state, batch)
    np.testing.assert_array_equal(np.asarray(p1["x"]), np.asarray(p2["x"]))
    assert float(met1["loss"]) == float(met2["loss"])


def test_flat_fused_step_bf16_params():
    """bf16 param leaves round-trip the packed fp32 buffer and are rounded
    back to bf16 inside the commit kernel (bf16_mask path)."""
    m, B, d = 2, 2, 37

    def loss_fn(p, b):
        x = p["x"].astype(jnp.float32)
        return 0.5 * jnp.mean(jnp.sum((x - b["t"]) ** 2, -1)) \
            + 0.5 * jnp.square(p["s"])

    p0 = {"x": jnp.zeros((d,), jnp.bfloat16), "s": jnp.asarray(1.0)}
    hists = {}
    for name in ("fused", "flat"):
        cfg = HOSGDConfig(tau=1 << 30, mu=1e-2, m=m, lr=0.1, zo_lr=0.1 / d,
                          engine=name, momentum=0.9)
        hists[name] = run_method(make_ho_sgd(loss_fn, cfg), p0,
                                 _quad_batches(m, B, d), 5)
    assert hists["flat"]["params"]["x"].dtype == jnp.bfloat16
    assert hists["flat"]["params"]["s"].dtype == jnp.float32
    # bf16 rounding of near-identical fp32 commits: bf16-eps agreement
    np.testing.assert_allclose(
        np.asarray(hists["flat"]["params"]["x"], np.float32),
        np.asarray(hists["fused"]["params"]["x"], np.float32),
        rtol=2e-2, atol=1e-3)
    np.testing.assert_allclose(hists["flat"]["loss"], hists["fused"]["loss"],
                               rtol=1e-3)


def test_flat_nonsgd_optimizer_falls_back_to_generic_path():
    """adam on flat takes the reconstruct-then-opt.apply path, which is the
    shared engine contract — bit-identical to tree under a bf16 accumulator."""
    from repro.opt.optimizers import adam, const_schedule

    m, B, d = 2, 2, 63
    p0 = {"x": jnp.zeros((d,))}
    hists = {}
    for name in ("tree", "flat"):
        cfg = HOSGDConfig(tau=1 << 30, mu=1e-3, m=m, lr=0.05, zo_lr=0.05 / d,
                          engine=name, acc_dtype="bfloat16")
        meth = make_ho_sgd(_quad_loss, cfg, opt=adam(const_schedule(0.05)))
        hists[name] = run_method(meth, p0, _quad_batches(m, B, d), 5)
    np.testing.assert_array_equal(np.asarray(hists["tree"]["params"]["x"]),
                                  np.asarray(hists["flat"]["params"]["x"]))
    assert hists["tree"]["loss"] == hists["flat"]["loss"]


def test_zo_step_flat_matches_fused_on_1x1_mesh():
    """distributed make_zo_step: the flat fused path (zo_auto branch) is
    loss/params-equivalent (rtol) to the fused engine's generic path."""
    from repro import compat
    from repro.core.distributed import make_zo_step
    from repro.launch.mesh import make_test_mesh
    from repro.opt.optimizers import const_schedule, sgd

    d = 130
    params = {"x": jnp.linspace(-1.0, 1.0, d)}
    batch = {"t": jnp.ones((4, d), jnp.float32)}
    mesh = make_test_mesh(data=1, model=1)
    outs = {}
    with compat.set_mesh(mesh):
        for name in ("fused", "flat"):
            ho = HOSGDConfig(tau=1 << 30, mu=1e-3, m=2, lr=0.05,
                             zo_lr=0.05 / d, engine=name, momentum=0.9)
            opt = sgd(const_schedule(ho.lr), ho.momentum)
            zo = jax.jit(make_zo_step(_quad_loss, mesh, ho, opt, m=2))
            p1, _, loss = zo(jnp.int32(3), params, opt.init(params), batch)
            outs[name] = (np.asarray(p1["x"]), float(loss))
    np.testing.assert_allclose(outs["flat"][0], outs["fused"][0],
                               rtol=1e-5, atol=1e-7)
    assert outs["flat"][1] == pytest.approx(outs["fused"][1], rel=1e-6)
