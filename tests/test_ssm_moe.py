"""Mamba SSM + MoE layer correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import moe as MOE
from repro.models import ssm as SSM

KEY = jax.random.key(0)


@pytest.fixture(scope="module")
def mamba():
    cfg = get_config("falcon-mamba-7b").reduced()
    p = SSM.init_mamba(jax.random.key(1), cfg, jnp.float32)
    return cfg, p


def test_mamba_forward_matches_sequential(mamba):
    """Associative-scan forward == step-by-step recurrence via decode."""
    cfg, p = mamba
    B, S = 2, 24
    x = jax.random.normal(KEY, (B, S, cfg.d_model)) * 0.3
    full = SSM.mamba_forward(cfg, p, x)
    state = SSM.init_mamba_state(cfg, B, jnp.float32)
    outs = []
    for t in range(S):
        o, state = SSM.mamba_decode(cfg, p, x[:, t : t + 1], state)
        outs.append(o[:, 0])
    step = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step),
                               rtol=2e-4, atol=2e-4)


def test_mamba_chunked_equals_unchunked(mamba):
    cfg, p = mamba
    B, S = 2, 32
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, cfg.d_model)) * 0.3
    a = SSM.mamba_forward(cfg, p, x)
    b = SSM.mamba_forward(cfg.with_(ssm_chunk=8), p, x)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


def test_mamba_causality(mamba):
    """Changing future inputs must not change past outputs."""
    cfg, p = mamba
    B, S = 1, 16
    x = jax.random.normal(KEY, (B, S, cfg.d_model)) * 0.3
    y1 = SSM.mamba_forward(cfg, p, x)
    x2 = x.at[:, 10:].set(7.0)
    y2 = SSM.mamba_forward(cfg, p, x2)
    np.testing.assert_allclose(np.asarray(y1[:, :10]), np.asarray(y2[:, :10]),
                               rtol=1e-5, atol=1e-6)
    assert bool(jnp.any(jnp.abs(y1[:, 10:] - y2[:, 10:]) > 1e-4))


# --------------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def moe():
    cfg = get_config("qwen3-moe-235b-a22b").reduced().with_(
        capacity_factor=8.0)  # big capacity: no token dropping in tests
    p = MOE.init_moe(jax.random.key(2), cfg, jnp.float32)
    return cfg, p


def test_moe_matches_dense_expert_loop(moe):
    """Capacity dispatch == explicit per-token top-k expert evaluation."""
    cfg, p = moe
    B, S = 2, 8
    x = jax.random.normal(KEY, (B, S, cfg.d_model)) * 0.5
    got, aux = MOE.moe_forward(cfg, p, x)

    xf = x.reshape(-1, cfg.d_model)
    logits = xf @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gate, ids = jax.lax.top_k(probs, cfg.top_k)
    gate = gate / gate.sum(-1, keepdims=True)
    want = jnp.zeros_like(xf)
    for t in range(xf.shape[0]):
        acc = jnp.zeros((cfg.d_model,))
        for j in range(cfg.top_k):
            e = int(ids[t, j])
            h = jax.nn.silu(xf[t] @ p["wg"][e]) * (xf[t] @ p["wu"][e])
            acc = acc + gate[t, j] * (h @ p["wd"][e])
        want = want.at[t].set(acc)
    np.testing.assert_allclose(np.asarray(got.reshape(-1, cfg.d_model)),
                               np.asarray(want), rtol=2e-4, atol=2e-4)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens():
    """With capacity factor << 1 most tokens are dropped (output ~ 0 for them)."""
    cfg = get_config("qwen3-moe-235b-a22b").reduced().with_(capacity_factor=1e-9)
    p = MOE.init_moe(jax.random.key(3), cfg, jnp.float32)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model))
    y, _ = MOE.moe_forward(cfg, p, x)
    # capacity is floored at 8 slots/expert; most of 32 tokens * k slots drop
    zero_rows = jnp.mean((jnp.abs(y).sum(-1) == 0).astype(jnp.float32))
    assert y.shape == x.shape


def test_moe_aux_loss_uniform_router_is_one():
    """Switch aux loss == 1 exactly when routing is perfectly uniform."""
    cfg = get_config("arctic-480b").reduced()
    p = MOE.init_moe(jax.random.key(4), cfg, jnp.float32)
    p = dict(p, router=jnp.zeros_like(p["router"]))  # uniform probs
    x = jax.random.normal(KEY, (2, 16, cfg.d_model))
    _, aux = MOE.moe_forward(cfg, p, x)
    # me_e = 1/E; ce_e sums to k -> aux = E * sum(1/E * ce) = k... for top-k
    assert float(aux) == pytest.approx(cfg.top_k, rel=1e-5)


def test_moe_capacity_helper():
    cfg = get_config("arctic-480b")
    C = MOE.moe_capacity(cfg, 1_048_576)
    assert C >= 1_048_576 * cfg.top_k * cfg.capacity_factor / cfg.n_experts
    assert C % 8 == 0
