"""repro.obs: span tracing, Perfetto export, trace-derived attribution.

Pins (ISSUE 8):
  (a) the legacy (time, kind, worker) tuple trace is BIT-IDENTICAL to the
      pre-obs event loop (golden fixture tests/golden/pre_pr8_traces.json,
      captured before the span refactor) — the tuple view is derived from
      the committed spans, so the determinism contract now pins the span
      path too;
  (b) the Perfetto export is deterministic: same spec seed => byte-identical
      JSON artifact; a different seed changes it;
  (c) span invariants: kinds from the fixed taxonomy, no negative durations,
      per-worker compute spans never overlap, every src_kind-bearing span
      round-trips into exactly the tuple trace;
  (d) trace-derived attribution equals the costs.exposed_comm_time closed
      forms within 1e-9, across collective kinds x overlap buckets;
  (e) TTFT decomposes exactly: ttft == queue_s + service_s per request, in
      both the continuous replay and the seed-sync baseline;
  (f) CSVLogger rejects unknown keys (no silent drop);
  (g) launch.hlo.async_overlap_stats counts the ops scheduled between async
      collective start/done pairs.
"""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.metrics import CSVLogger
from repro.obs import (
    KINDS,
    Span,
    Tracer,
    attribution,
    attribution_from_file,
    dumps,
    format_report,
    load_trace_events,
    slot_lane,
    spans_from_events,
    trace_events,
    validate_trace_events,
    worker_lane,
    write_trace,
)
from repro.launch import hlo
from repro.sim import (
    ClusterSpec,
    Topology,
    compute_model_for,
    make_sim_methods,
    simulate,
)
from repro.sim.costs import exposed_comm_time

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "pre_pr8_traces.json")

QUAD_D, QUAD_M = 48, 4
N_ITERS, TAU = 10, 4


def quad_loss(params, batch):
    return 0.5 * jnp.mean(jnp.sum((params["x"] - batch["t"]) ** 2, -1))


QUAD_PARAMS = {"x": jnp.zeros((QUAD_D,), jnp.float32)}
QUAD_BATCH = {"t": jnp.ones((2 * QUAD_M, QUAD_D), jnp.float32)}


def _batches():
    while True:
        yield QUAD_BATCH


def run_sim(spec, which="ho_sgd", overlap=1, n_iters=N_ITERS):
    sm = make_sim_methods(quad_loss, QUAD_PARAMS, spec, tau=TAU, lr=0.1,
                          zo_lr=0.05, which=[which],
                          overlap_buckets=overlap)[which]
    compute = compute_model_for(QUAD_PARAMS, spec, 2)
    return simulate(sm, QUAD_PARAMS, _batches(), spec, n_iters,
                    compute=compute)


BASE = ClusterSpec(m=QUAD_M, flops_per_sec=1e9, alpha=1e-5, bandwidth=1e6,
                   straggler_prob=0.3, straggler_slowdown=4.0,
                   jitter_sigma=0.1, seed=1234)

GOLDEN_SPECS = {
    "sync_b1": (BASE, 1),
    "sync_b4": (BASE, 4),
    "async2_b1": (BASE.with_(max_staleness=2), 1),
    "ring2pod_b4": (BASE.with_(collective="ring",
                               topology=Topology(pods=2, inter_alpha=1e-4,
                                                 inter_bandwidth=2.5e5)), 4),
    "elastic_b1": (BASE.with_(elastic=True, fail_rate=5000.0, downtime=5e-5,
                              restart_time=1e-5), 1),
}

_cache = {}


def cached_run(name):
    if name not in _cache:
        spec, ov = GOLDEN_SPECS[name]
        _cache[name] = run_sim(spec, overlap=ov)
    return _cache[name]


# --------------------------------------------------------------------------- #
# (a) the tuple trace is a derived view, bit-identical to the pre-obs loop
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("name", sorted(GOLDEN_SPECS))
def test_tuple_trace_unchanged_vs_pre_pr8(name):
    with open(GOLDEN) as f:
        golden = json.load(f)
    res = cached_run(name)
    assert [[t, k, w] for (t, k, w) in res.trace] == golden[name]


def test_trace_is_derived_from_spans():
    res = cached_run("async2_b1")
    derived = [(s.t1, s.src_kind, s.worker) for s in res.spans
               if s.src_kind is not None]
    assert derived == res.trace
    # annotation spans exist (queue waits / barrier waits / overlap detail)
    # but never enter the tuple view
    assert len(res.spans) > len(res.trace)


# --------------------------------------------------------------------------- #
# (b) deterministic export: same seed => byte-identical artifact
# --------------------------------------------------------------------------- #
def test_export_byte_identical_per_seed(tmp_path):
    a = run_sim(GOLDEN_SPECS["sync_b4"][0], overlap=4)
    b = run_sim(GOLDEN_SPECS["sync_b4"][0], overlap=4)
    sa, sb = dumps(a.spans), dumps(b.spans)
    assert sa == sb
    pa = write_trace(str(tmp_path / "a.json"), a.spans, title="t")
    pb = write_trace(str(tmp_path / "b.json"), b.spans, title="t")
    assert open(pa, "rb").read() == open(pb, "rb").read()


def test_export_differs_across_seeds():
    a = run_sim(BASE, overlap=1, n_iters=4)
    b = run_sim(BASE.with_(seed=99), overlap=1, n_iters=4)
    assert dumps(a.spans) != dumps(b.spans)


def test_trace_event_schema():
    res = cached_run("sync_b1")
    events = trace_events(res.spans, title="quad")
    validate_trace_events(events)
    # one process_name + one thread_name per lane, lanes in first-appearance
    # order; every X event lands on a declared lane
    meta = [e for e in events if e["ph"] == "M"]
    lanes = [e["args"]["name"] for e in meta if e["name"] == "thread_name"]
    assert lanes[0] in (worker_lane(0), "cluster") or lanes[0].startswith("worker/")
    tids = {e["tid"] for e in events if e["ph"] == "X"}
    assert tids <= set(range(len(lanes)))


# --------------------------------------------------------------------------- #
# (c) span invariants
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("name", sorted(GOLDEN_SPECS))
def test_span_invariants(name):
    res = cached_run(name)
    per_worker = {}
    for s in res.spans:
        assert s.kind in KINDS
        assert s.t1 >= s.t0 - 1e-12
        if s.kind == "compute" and s.worker >= 0:
            per_worker.setdefault(s.worker, []).append((s.t0, s.t1))
            assert s.lane == worker_lane(s.worker)
    # a worker computes one thing at a time: compute spans on one lane are
    # disjoint (touching endpoints allowed)
    for w, iv in per_worker.items():
        iv.sort()
        for (a0, a1), (b0, b1) in zip(iv, iv[1:]):
            assert b0 >= a1 - 1e-9, (w, (a0, a1), (b0, b1))


def test_async_round_emits_queue_and_comm_annotations():
    spec = BASE.with_(max_staleness=2, topology=Topology(
        pods=2, inter_alpha=1e-4, inter_bandwidth=2.5e5))
    res = run_sim(spec, overlap=1)
    kinds = {s.kind for s in res.spans}
    assert "comm.exposed" in kinds
    assert "queue.contention" in kinds  # shared-link waits made visible


# --------------------------------------------------------------------------- #
# (d) attribution: trace == closed form, across collectives x buckets
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("collective", ["flat", "ring", "tree"])
@pytest.mark.parametrize("buckets", [1, 4])
def test_attribution_matches_closed_form(collective, buckets):
    spec = ClusterSpec(m=QUAD_M, flops_per_sec=1e9, alpha=1e-6,
                       bandwidth=5e7, collective=collective, seed=7)
    res = run_sim(spec, overlap=buckets)
    att = attribution(res.spans)
    compute = compute_model_for(QUAD_PARAMS, spec, 2)
    cm = spec.collective_model
    closed = 0.0
    for order, nb in zip(res.orders, res.comm_bytes):
        dt = compute.time(2.0, 0.0) if order == 0 else compute.time(0.0, 1.0)
        closed += exposed_comm_time(cm, nb, spec.m, buckets, dt)
    assert abs(att["kind_seconds"]["comm.exposed"] - closed) <= 1e-9
    assert abs(closed - res.comm_s) <= 1e-9          # and the runner agrees
    # no stragglers/jitter: makespan is the last commit time exactly
    assert abs(att["makespan_s"] - res.sim_seconds) <= 1e-9
    assert att["kind_bytes"]["comm.exposed"] == res.bytes_total


def test_attribution_roundtrips_through_file(tmp_path):
    res = cached_run("ring2pod_b4")
    path = write_trace(str(tmp_path / "t.json"), res.spans, title="rt")
    att_file = attribution_from_file(path)
    att_live = attribution(res.spans)
    assert att_file["n_spans"] == att_live["n_spans"]
    assert att_file["kind_bytes"] == att_live["kind_bytes"]
    for k in KINDS:
        assert att_file["kind_seconds"][k] == pytest.approx(
            att_live["kind_seconds"][k], abs=1e-12)
    # durations survive the µs round-trip exactly (export stores dur, the
    # reader reconstructs t1 = t0 + dur/1e6)
    back = spans_from_events(load_trace_events(path))
    assert len(back) == sum(1 for _ in res.spans)
    for orig, rt in zip(res.spans, back):
        assert rt.duration == pytest.approx(orig.duration, abs=1e-15)
        assert rt.kind == orig.kind and rt.lane == orig.lane
    lines = format_report(att_file, title="rt")
    assert any("exposed_comm_fraction" in ln for ln in lines)


# --------------------------------------------------------------------------- #
# wall-clock tracer
# --------------------------------------------------------------------------- #
def test_wall_tracer_nesting_and_mutation():
    tr = Tracer(clock="wall")
    with tr.span("compute", "train", name="outer") as outer:
        with tr.span("checkpoint", "train", name="inner"):
            pass
        outer.nbytes = 123
    tr.counter(tr.now(), "train", "ledger_bytes", 123.0)
    assert len(tr.spans) == 2
    out, inner = tr.spans[0], tr.spans[1]
    assert out.name == "outer" and inner.name == "inner"
    assert inner.parent == 0 and out.parent == -1
    assert out.t0 <= inner.t0 and inner.t1 <= out.t1
    assert out.nbytes == 123
    validate_trace_events(trace_events(tr.spans, tr.counters))


def test_sim_tracer_rejects_wall_api():
    tr = Tracer(clock="sim")
    with pytest.raises(AssertionError):
        tr.now()
    with pytest.raises(AssertionError):
        with tr.span("compute", "x"):
            pass
    with pytest.raises(AssertionError):
        Span("not-a-kind", "lane", 0.0, 1.0)
    with pytest.raises(AssertionError):
        Span("compute", "lane", 1.0, 0.5)


# --------------------------------------------------------------------------- #
# (e) TTFT decomposition (queue_s + service_s)
# --------------------------------------------------------------------------- #
def _serving_stack():
    jax = pytest.importorskip("jax")
    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.serving import Engine, ServeConfig
    cfg = get_config("qwen3-14b").reduced().with_(remat=False)
    params = T.init_model(jax.random.key(0), cfg)
    return cfg, params, Engine, ServeConfig


def test_ttft_decomposition_and_traffic_spans():
    from repro.sim.traffic import TrafficSpec, replay, serve_compute_model
    cfg, params, Engine, ServeConfig = _serving_stack()
    spec = TrafficSpec(rate=400.0, n_requests=10, prompt_lens=(4, 9),
                       out_lens=(3, 6), seed=3)
    cm = serve_compute_model(cfg, flops_per_sec=1e9)
    tracer = Tracer(clock="sim")
    eng = Engine(cfg, params, ServeConfig(max_seq=spec.required_max_seq(),
                                          slots=2))
    res = replay(eng, spec, cm, tracer=tracer)
    for r in res.rows:
        assert r["queue_s"] >= 0.0 and r["service_s"] > 0.0
        assert r["ttft"] == pytest.approx(r["queue_s"] + r["service_s"],
                                          abs=1e-12)
    for k in ("p50_queue_s", "p99_queue_s", "p50_service_s", "p99_service_s"):
        assert k in res.summary
    # tracing is an observer: an untraced replay is bit-identical
    eng2 = Engine(cfg, params, ServeConfig(max_seq=spec.required_max_seq(),
                                           slots=2))
    res2 = replay(eng2, spec, cm)
    assert res2.events == res.events
    assert res2.rows == res.rows
    assert res2.summary == res.summary
    # per-request lifecycle spans on slot lanes: one prefill per request,
    # prefill duration == service_s, queue span == queue_s
    prefills = [s for s in tracer.spans if s.kind == "prefill"]
    assert len(prefills) == spec.n_requests
    by_rid = {int(s.name.split("/r")[1]): s
              for s in tracer.spans if s.kind == "queue.contention"}
    for r in res.rows:
        q = by_rid[r["rid"]]
        assert q.duration == pytest.approx(r["queue_s"], abs=1e-12)
        assert q.lane.startswith("slot/")
    assert any(s.kind == "decode" for s in tracer.spans)
    validate_trace_events(trace_events(tracer.spans, tracer.counters))


def test_seed_sync_ttft_decomposition():
    from repro.sim.traffic import (TrafficSpec, replay_seed_sync,
                                   serve_compute_model)
    from repro.configs import get_config
    cfg = get_config("qwen3-14b").reduced()
    spec = TrafficSpec(rate=200.0, n_requests=9, prompt_lens=(4, 8),
                       out_lens=(3, 5), seed=11)
    res = replay_seed_sync(spec, serve_compute_model(cfg, 1e9), batch=4)
    for r in res.rows:
        assert r["ttft"] == pytest.approx(r["queue_s"] + r["service_s"],
                                          abs=1e-12)
    assert "p99_queue_s" in res.summary


# --------------------------------------------------------------------------- #
# (f) CSVLogger: unknown keys raise instead of silently dropping
# --------------------------------------------------------------------------- #
def test_csvlogger_unknown_key_raises(tmp_path):
    path = str(tmp_path / "log.csv")
    with CSVLogger(path, ["a", "b"]) as log:
        log.log(a=1, b=2)
        with pytest.raises(ValueError, match="unknown keys"):
            log.log(a=1, typo=3)
    # validation applies to the disabled logger too (path=None)
    nolog = CSVLogger(None, ["a"])
    nolog.log(a=1)
    with pytest.raises(ValueError, match="unknown keys"):
        nolog.log(zz=1)


# --------------------------------------------------------------------------- #
# (g) HLO async-overlap stats
# --------------------------------------------------------------------------- #
SYNTH_HLO = """\
ENTRY %main {
  %p0 = f32[128]{0} parameter(0)
  %ar-start = f32[128]{0} all-reduce-start(%p0), replica_groups={{0,1}}
  %m0 = f32[128]{0} multiply(%p0, %p0)
  %m1 = f32[128]{0} add(%m0, %p0)
  %ar-done = f32[128]{0} all-reduce-done(%ar-start)
  %ag-start = f32[256]{0} all-gather-start(%m1), replica_groups={{0,1}}
  %ag-done = f32[256]{0} all-gather-done(%ag-start)
  ROOT %out = f32[128]{0} add(%ar-done, %m1)
}
"""


def test_async_overlap_stats_counts_gaps():
    st = hlo.async_overlap_stats(SYNTH_HLO)
    assert st["pairs"] == 2
    assert st["by_kind"] == {"all-reduce": 1, "all-gather": 1}
    # two ops (%m0, %m1) between ar-start/done; zero between ag pair
    assert st["overlapped_pairs"] == 1
    assert st["max_gap"] == 2
    assert st["mean_gap"] == pytest.approx(1.0)


def test_async_overlap_stats_empty_on_sync_hlo():
    st = hlo.async_overlap_stats("""\
ENTRY %main {
  %p0 = f32[8]{0} parameter(0)
  %ar = f32[8]{0} all-reduce(%p0), replica_groups={{0,1}}
  ROOT %r = f32[8]{0} add(%ar, %p0)
}
""")
    assert st["pairs"] == 0 and st["overlapped_pairs"] == 0
    assert st["mean_gap"] == 0.0 and st["max_gap"] == 0


# --------------------------------------------------------------------------- #
# slot lanes helper
# --------------------------------------------------------------------------- #
def test_lane_helpers():
    assert worker_lane(3) == "worker/3"
    assert worker_lane(-1) == "cluster"
    assert slot_lane(2) == "slot/2"
    assert slot_lane(-1) == "slot/prefill-only"
