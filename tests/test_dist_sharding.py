"""repro.dist.sharding: the spec contract every layer builds on.

Spec *placement* logic is pure (only reads ``mesh.shape``), so most tests
drive it with AbstractMesh shapes a single CPU device could never host;
``test_worker_axes_real_mesh`` exercises the same rules on a real 4x2 mesh
when the process has devices for one (the CI tier-1 run forces 8).
"""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import get_config
from repro.dist.sharding import (
    batch_specs, cache_specs, n_workers, param_specs, worker_axes,
)
from repro.models import transformer as T


def mesh_of(*axes):
    return AbstractMesh(tuple(axes))


POD_MESH = mesh_of(("data", 16), ("model", 16))
MULTIPOD_MESH = mesh_of(("pod", 2), ("data", 16), ("model", 16))


def test_worker_axes_and_count():
    assert worker_axes(POD_MESH) == ("data",)
    assert worker_axes(MULTIPOD_MESH) == ("pod", "data")
    assert n_workers(POD_MESH) == 16
    assert n_workers(MULTIPOD_MESH) == 32
    assert worker_axes(mesh_of(("model", 4))) == ()
    assert n_workers(mesh_of(("model", 4))) == 1


def abstract_params(cfg):
    return jax.eval_shape(lambda k: T.init_model(k, cfg), jax.random.key(0))


def specs_by_path(cfg, mesh):
    params = abstract_params(cfg)
    specs = param_specs(cfg, params, mesh)
    flat, _ = jax.tree_util.tree_flatten_with_path(specs)
    return {
        "/".join(str(k.key) for k in path): s for path, s in flat
    }, params


def test_param_specs_tensor_parallel_rules():
    cfg = get_config("gemma2-2b")
    by, _ = specs_by_path(cfg, POD_MESH)
    # column-parallel: output dim over model; row-parallel: contraction dim
    assert by["layers/attn/wq"] == P(None, None, "model")
    assert by["layers/attn/wo"] == P(None, "model")
    assert by["layers/mlp/wg"] == P(None, None, "model")
    assert by["layers/mlp/wd"] == P(None, "model")
    # norms replicated
    assert by["layers/norm1/scale"] == P()
    assert by["final_norm/scale"] == P()
    # embed: vocab rows over model (gemma2 ties the head to embed.T)
    assert by["embed"] == P("model")
    by_q, _ = specs_by_path(get_config("qwen3-14b"), POD_MESH)
    assert by_q["head"] == P(None, "model")   # untied head: vocab cols


def test_param_specs_never_name_worker_axes_without_fsdp():
    for arch in ("gemma2-2b", "qwen3-moe-235b-a22b", "falcon-mamba-7b"):
        cfg = get_config(arch)
        if cfg.fsdp:
            continue
        by, _ = specs_by_path(cfg, MULTIPOD_MESH)
        for path, spec in by.items():
            named = {a for part in spec for a in
                     ((part,) if isinstance(part, str) else (part or ()))}
            assert "data" not in named and "pod" not in named, (path, spec)


def test_param_specs_divisibility_guard():
    # reduced configs have dims a 16-way model axis can't divide: replicate
    cfg = get_config("gemma2-2b").reduced()
    by, params = specs_by_path(cfg, mesh_of(("data", 4), ("model", 7)))
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    shapes = {"/".join(str(k.key) for k in p): x.shape for p, x in flat}
    for path, spec in by.items():
        for dim, part in enumerate(spec):
            if part == "model":
                assert shapes[path][dim] % 7 == 0, (path, spec, shapes[path])


def test_param_specs_fsdp_adds_data_axis():
    cfg = get_config("gemma2-2b").with_(fsdp=True)
    by, _ = specs_by_path(cfg, POD_MESH)
    named = set()
    for spec in by.values():
        for part in spec:
            named.update((part,) if isinstance(part, str) else (part or ()))
    assert "data" in named and "model" in named


def test_param_specs_fsdp_moe_expert_dim_over_data():
    cfg = get_config("qwen3-moe-235b-a22b")
    assert cfg.is_moe
    by, _ = specs_by_path(cfg.with_(fsdp=True), POD_MESH)
    # stacked (L, E, D, F): expert dim over data, hidden over model — the
    # contract moe._expert_spec's dispatch constraints assume
    assert by["layers/moe/wg"] == P(None, "data", None, "model")
    assert by["layers/moe/wd"] == P(None, "data", "model")


def test_batch_specs_worker_leading_dim():
    batch = {"tokens": jax.ShapeDtypeStruct((64, 128), jnp.int32),
             "labels": jax.ShapeDtypeStruct((64, 128), jnp.int32)}
    specs = batch_specs(MULTIPOD_MESH, batch)
    assert specs["tokens"] == P(("pod", "data"))
    # non-divisible leading dim -> replicated, not an unshardable program
    odd = {"tokens": jax.ShapeDtypeStruct((7, 128), jnp.int32)}
    assert batch_specs(MULTIPOD_MESH, odd)["tokens"] == P()
    scalar = {"pos": jax.ShapeDtypeStruct((), jnp.int32)}
    assert batch_specs(MULTIPOD_MESH, scalar)["pos"] == P()


def test_cache_specs_decode_and_long_context():
    cfg = get_config("gemma2-2b")
    caches = jax.eval_shape(
        lambda: T.init_caches(cfg, 128, 4096, jnp.bfloat16))
    specs = cache_specs(cfg, POD_MESH, caches, seq_sharded=False)
    # (L, B, S, KV, hd): batch over workers; kv-heads over model when they
    # divide, else head_dim
    kspec = specs["k"]
    assert kspec[1] == ("data",)
    assert "model" in (kspec[3] if len(kspec) > 3 else None,
                       kspec[4] if len(kspec) > 4 else None)
    # long_500k: sequence carries the worker axes, batch=1 replicated
    long = jax.eval_shape(lambda: T.init_caches(cfg, 1, 1 << 19, jnp.bfloat16))
    specs = cache_specs(cfg, POD_MESH, long, seq_sharded=True)
    assert specs["k"][2] == ("data",)
    assert len(specs["k"]) < 2 or specs["k"][1] is None


def test_cache_specs_ssm():
    cfg = get_config("falcon-mamba-7b")
    caches = jax.eval_shape(lambda: T.init_caches(cfg, 128, 1024, jnp.bfloat16))
    specs = cache_specs(cfg, POD_MESH, caches, seq_sharded=False)
    assert specs["conv"][1] == ("data",) and specs["conv"][3] == "model"
    assert specs["ssm"][1] == ("data",) and specs["ssm"][2] == "model"


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs the CI 8-device tier-1 run")
def test_worker_axes_real_mesh():
    """The spec contract on a real multi-device mesh (CI forces 8 devices)."""
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    assert worker_axes(mesh) == ("data",) and n_workers(mesh) == 4
    cfg = get_config("gemma2-2b").reduced()
    params = abstract_params(cfg)
    specs = param_specs(cfg, params, mesh)
    from jax.sharding import NamedSharding
    # every spec is realizable on the mesh (NamedSharding construction checks)
    jax.tree.map(lambda x, s: NamedSharding(mesh, s), params, specs,
                 is_leaf=lambda x: isinstance(x, P))
    batch = {"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32)}
    assert batch_specs(mesh, batch)["tokens"] == P(("data",))
