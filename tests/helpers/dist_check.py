"""Subprocess helper: distributed HO-SGD on an 8-device mesh must equal the
single-host reference (run by test_distributed.py with its own XLA_FLAGS)."""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.configs import get_config
from repro.core.distributed import make_distributed_ho_sgd
from repro.core.ho_sgd import HOSGDConfig, make_ho_sgd
from repro.dist.sharding import batch_specs, named, param_specs
from repro.models import transformer as T
from repro.opt.optimizers import const_schedule, sgd


def main():
    assert jax.device_count() == 8, jax.device_count()
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = get_config("qwen3-14b").reduced()
    params = T.init_model(jax.random.key(0), cfg)
    loss_fn = lambda p, b: T.loss_fn(cfg, p, b)
    d = sum(x.size for x in jax.tree.leaves(params))
    ho = HOSGDConfig(tau=4, mu=1e-3, m=4, lr=0.05, zo_lr=0.05 / d)
    opt = sgd(const_schedule(ho.lr))
    fo, zo = make_distributed_ho_sgd(loss_fn, mesh, ho, opt, model_cfg=cfg,
                                     params_like=params)

    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (8, 16)).astype(np.int32)
    labels = np.concatenate([toks[:, 1:], -np.ones((8, 1), np.int32)], 1)
    batch = {"tokens": toks, "labels": labels}

    with compat.set_mesh(mesh):
        params_d = jax.device_put(params, named(mesh, param_specs(cfg, params, mesh)))
        batch_d = jax.device_put(batch, named(mesh, batch_specs(mesh, batch)))
        opt_state = opt.init(params_d)
        fo_j, zo_j = jax.jit(fo), jax.jit(zo)
        p1, s1, l_fo = fo_j(jnp.int32(0), params_d, opt_state, batch_d)
        p2, s2, l_zo = zo_j(jnp.int32(1), p1, s1, batch_d)
        assert np.isfinite(float(l_fo)) and np.isfinite(float(l_zo))
        # descent over a hybrid schedule
        p, s = p2, s2
        for t in range(2, 14):
            step = fo_j if t % ho.tau == 0 else zo_j
            p, s, l = step(jnp.int32(t), p, s, batch_d)
        assert float(l) < float(l_fo), (float(l), float(l_fo))

        # one distributed ZO step == single-host reference (same seed/t)
        pz, _, _ = zo_j(jnp.int32(5), params_d, opt.init(params_d), batch_d)
    ref = make_ho_sgd(loss_fn, HOSGDConfig(tau=1 << 30, mu=ho.mu, m=4,
                                           lr=ho.lr, zo_lr=ho.zo_lr,
                                           seed=ho.seed))
    pr, _, _ = ref.step(5, params, ref.init(params), batch)
    diff = max(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(jax.device_get(pz)), jax.tree.leaves(pr))
    )
    assert diff < 2e-5, diff
    print("DIST_CHECK_OK", diff)


if __name__ == "__main__":
    main()
