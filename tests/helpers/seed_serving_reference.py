"""VERBATIM reference copy of the SEED serving engine (pre-ISSUE-6).

The continuous-batching engine is pinned token-identical to this synchronous
path at temperature 0 (same pattern as the PR-2 program references in
test_rounds_equivalence.py).  Classes are renamed ``Seed*``; nothing else
may change.  Note the two seed bugs this copy preserves on purpose:
``eos_id`` is dead (never checked) and the sampling path folds the step
counter twice (``generate`` folds ``key`` per step and ``_sample`` folds
again) — the rewrite fixes both, so temperature>0 outputs are NOT expected
to match, only the temperature-0 token streams are.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as T


@dataclass
class SeedServeConfig:
    max_seq: int
    temperature: float = 0.0
    eos_id: int = -1          # disabled by default (synthetic vocabularies)


class SeedEngine:
    def __init__(self, cfg: ModelConfig, params, serve_cfg: SeedServeConfig):
        assert not cfg.encoder_only, "encoder-only models don't decode"
        self.cfg = cfg
        self.params = params
        self.sc = serve_cfg
        self._decode = jax.jit(
            lambda p, tok, pos, caches: T.decode_step(cfg, p, tok, pos, caches)
        )
        self._prefill = jax.jit(lambda p, batch: T.prefill(cfg, p, batch))

    def _pad_prompts(self, prompts: List[List[int]]):
        """Right-align prompts into a rectangle (left padding with token 0)."""
        B = len(prompts)
        L = max(len(p) for p in prompts)
        toks = np.zeros((B, L), np.int32)
        for i, p in enumerate(prompts):
            toks[i, L - len(p):] = p
        return jnp.asarray(toks), L

    def generate(self, prompts: List[List[int]], max_new: int,
                 key: Optional[jax.Array] = None) -> List[List[int]]:
        cfg, sc = self.cfg, self.sc
        toks, L = self._pad_prompts(prompts)
        B = toks.shape[0]
        S = sc.max_seq
        assert L + max_new <= S, "max_seq too small"
        # prefill over the prompt, then pad caches out to max_seq
        batch: Dict = {"tokens": toks}
        logits, caches = self._prefill(self.params, batch)
        caches = jax.tree.map(
            lambda c: jnp.pad(
                c, [(0, 0), (0, 0), (0, S - c.shape[2]), (0, 0), (0, 0)]
            ) if c.ndim == 5 and c.shape[2] == L else c,
            caches,
        )
        out = [list(p) for p in prompts]
        tok = self._sample(logits, key, 0)
        for step in range(max_new):
            for i in range(B):
                out[i].append(int(tok[i]))
            if step == max_new - 1:
                break
            pos = jnp.int32(L + step)
            logits, caches = self._decode(self.params, tok, pos, caches)
            key = jax.random.fold_in(key, step) if key is not None else None
            tok = self._sample(logits, key, step + 1)
        return out

    def _sample(self, logits: jax.Array, key, step: int) -> jax.Array:
        if self.sc.temperature <= 0 or key is None:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            jax.random.fold_in(key, step), logits / self.sc.temperature
        ).astype(jnp.int32)


def seed_serve_step(cfg: ModelConfig, params, token, pos, caches):
    """The decode-shape dry-run target: one new token, full-length KV cache."""
    return T.decode_step(cfg, params, token, pos, caches)
