"""Subprocess helper: measured communication on a REAL 4-worker (4x2) mesh
must reproduce Table 1 — ZO moves exactly 4*m bytes (independent of d), the
dense FO all-reduce 4*d, and a QSGD-compressed FO step strictly less than
4*d.  Run by test_distributed.py with its own XLA_FLAGS."""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
from repro import compat
from repro.core.distributed import make_distributed_ho_sgd
from repro.core.ho_sgd import HOSGDConfig
from repro.dist import CommLedger, get_compressor
from repro.dist.sharding import batch_specs, n_workers, named
from repro.opt.optimizers import const_schedule, sgd


def quad_loss(params, batch):
    return 0.5 * jnp.mean(jnp.sum((params["x"] - batch["t"]) ** 2, -1))


def run(mesh, d, compressor=None):
    m = n_workers(mesh)
    ho = HOSGDConfig(tau=4, mu=1e-3, m=m, lr=0.05, zo_lr=0.05 / d)
    opt = sgd(const_schedule(ho.lr))
    fo, zo = make_distributed_ho_sgd(quad_loss, mesh, ho, opt,
                                     compressor=compressor)
    ledger = CommLedger()
    fo_j, zo_j = ledger.wrap("fo", jax.jit(fo)), ledger.wrap("zo", jax.jit(zo))
    with compat.set_mesh(mesh):
        params = {"x": jnp.zeros((d,), jnp.float32)}
        state = opt.init(params)
        batch = {"t": jnp.ones((8 * m, d), jnp.float32)}
        batch = jax.device_put(batch, named(mesh, batch_specs(mesh, batch)))
        for t in range(8):
            step = fo_j if t % ho.tau == 0 else zo_j
            params, state, loss = step(jnp.int32(t), params, state, batch)
        assert np.isfinite(float(loss))
    return ledger, m


def main():
    assert jax.device_count() == 8, jax.device_count()
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    d = 4096

    ledger, m = run(mesh, d)
    assert m == 4, m
    # Table 1, measured: ZO is 4*m bytes — independent of d — FO is 4*d
    assert ledger.bytes_per_step("zo") == 4 * m, ledger.summary()
    assert ledger.bytes_per_step("fo") == 4 * d, ledger.summary()
    amortized = ledger.total_bytes() / 8
    analytic = 4 * (d + 3 * m) / 4
    assert abs(amortized - analytic) < 1e-9, (amortized, analytic)

    qledger, _ = run(mesh, d, compressor=get_compressor("qsgd"))
    assert qledger.bytes_per_step("fo") < 4 * d, qledger.summary()
    assert qledger.bytes_per_step("zo") == 4 * m, qledger.summary()

    print("LEDGER_CHECK_OK",
          ledger.bytes_per_step("zo"), ledger.bytes_per_step("fo"),
          qledger.bytes_per_step("fo"))


if __name__ == "__main__":
    main()
