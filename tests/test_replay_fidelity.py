"""Replay-fidelity regression suite (ISSUE 5).

The PR-4 monolithic replay could only REPRICE async/elastic scenarios — the
step programs always computed with all m in-program workers, so the loss
trajectory was invariant to membership and staleness.  The per-worker
replay (the default) closes that caveat:

* on a synchronous full-membership spec it is trace- AND loss-bit-identical
  to the monolithic replay (every round runs through the SAME monolithic
  jitted program — no new numerics on the honest path);
* with ``elastic`` or ``max_staleness > 0`` the trajectory now measurably
  DIVERGES from the full-W run — and the same assertions FAIL against the
  old monolithic replay, which is pinned here too (its pricing-only
  contract is the regression reference);
* the live-W collective prices the payload each active worker actually
  sent (ZO rounds book 4 × live-W bytes, faithful QSGD ``nbytes`` ×
  live-W).
"""
import jax
import jax.numpy as jnp
import pytest

from repro.dist.compress import qsgd
from repro.sim import ClusterSpec, compute_model_for, make_sim_methods, simulate

D, M = 48, 4
TAU, N_ITERS = 4, 12


def quad_loss(params, batch):
    return 0.5 * jnp.mean(jnp.sum((params["x"] - batch["t"]) ** 2, -1))


def problem():
    return {"x": jnp.zeros((D,), jnp.float32)}


def batches():
    i = 0
    while True:
        yield {"t": jnp.full((2 * M, D), 1.0 + 0.1 * (i % 7), jnp.float32)}
        i += 1


def run(spec, replay, which="ho_sgd", n=N_ITERS, codec=None,
        compress_mode="per_worker"):
    params = problem()
    sm = make_sim_methods(quad_loss, params, spec, tau=TAU, lr=0.1,
                          zo_lr=0.05, codec=codec,
                          compress_mode=compress_mode,
                          which=[which])[which]
    return simulate(sm, params, batches(), spec, n,
                    compute=compute_model_for(params, spec, 2), replay=replay)


BASE = ClusterSpec(m=M, flops_per_sec=1e9, bandwidth=1e6, seed=0)
#: deterministic heterogeneity: worker 3 is 4x slower, so under bounded
#: staleness the fast workers genuinely run ahead (stale views realized)
HETERO = BASE.with_(rel_speeds=(1.0, 1.0, 1.0, 0.25), max_staleness=2)
ELASTIC = BASE.with_(elastic=True, fail_rate=5000.0, downtime=5e-5,
                     restart_time=1e-5, jitter_sigma=0.1)


# --------------------------------------------------------------------------- #
# sync full membership: per-worker == monolithic, bit for bit
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("which", ["ho_sgd", "ho_sgd_adaptive", "pa_sgd",
                                  "pa_gossip", "qsgd"])
def test_sync_per_worker_replay_bit_identical_to_monolithic(which):
    pw = run(BASE, "per_worker", which=which)
    mono = run(BASE, "monolithic", which=which)
    assert pw.trace == mono.trace
    assert pw.losses == mono.losses
    assert pw.comm_bytes == mono.comm_bytes
    for a, b in zip(jax.tree.leaves(pw.params), jax.tree.leaves(mono.params)):
        assert jnp.array_equal(a, b)


# --------------------------------------------------------------------------- #
# bounded staleness: stale views change the trajectory (and ONLY the
# per-worker replay can express that)
# --------------------------------------------------------------------------- #
def test_staleness_diverges_per_worker_but_not_monolithic():
    pw = run(HETERO, "per_worker")
    mono = run(HETERO, "monolithic")
    mono_sync = run(HETERO.with_(max_staleness=0), "monolithic")
    # the old replay: staleness repriced, trajectory untouched — the PR-4
    # caveat this suite regression-pins
    assert mono.losses == mono_sync.losses
    # the per-worker replay: fast workers evaluate at the params they
    # actually had — the trajectory measurably diverges
    assert pw.losses != mono.losses
    assert any(abs(a - b) > 1e-6 for a, b in zip(pw.losses, mono.losses))
    # pricing and event structure are a pure function of the cost models —
    # identical across replay modes (the divergence is in the MATH)
    assert pw.trace == mono.trace
    assert pw.orders == mono.orders


def test_staleness_views_survive_bulk_rollback():
    """A bulk-synchronous failure rewinds t but NOT the committed event
    history; view selection must index the current lineage's commits
    (truncated on restore), or every post-rollback async round silently
    degrades to current-params views.  Regression: stale-view divergence
    must still be present in the rounds committed AFTER the last restore,
    and the run stays deterministic across rollbacks."""
    spec = HETERO.with_(fail_rate=500.0, ckpt_every=2, restart_time=1e-4,
                        seed=3)
    pw = run(spec, "per_worker", n=24)
    mono = run(spec, "monolithic", n=24)
    assert pw.failures > 0
    assert pw.trace == mono.trace          # pricing identical either way
    last_restore = max(t for t, k, _ in pw.trace if k == "restore")
    post = [i for i, tm in enumerate(pw.times) if tm > last_restore]
    assert post, "no rounds committed after the last restore"
    assert any(pw.losses[i] != mono.losses[i] for i in post), \
        "staleness views stopped engaging after a rollback"
    again = run(spec, "per_worker", n=24)
    assert pw.trace == again.trace and pw.losses == again.losses


def test_staleness_divergence_requires_lagging_workers():
    """Homogeneous cluster, no jitter, contention off: nobody ever lags,
    every view is current, and the per-worker replay stays on the
    monolithic fast path — bit-identical even with max_staleness > 0.

    With the default shared-link contention, even homogeneous async
    exchanges serialize FIFO through the pod link, so workers genuinely
    finish at different times and stale views engage — the latency-honest
    counterpart, pinned below."""
    spec = BASE.with_(max_staleness=2, contention=False)
    pw = run(spec, "per_worker")
    mono = run(spec, "monolithic")
    assert pw.losses == mono.losses and pw.trace == mono.trace
    # contention on (the default): the serialized link staggers otherwise
    # identical workers, lagging views are real, trajectories diverge
    contended = run(BASE.with_(max_staleness=2), "per_worker")
    assert contended.losses != mono.losses


# --------------------------------------------------------------------------- #
# elastic membership: only the live workers' shards enter the round
# --------------------------------------------------------------------------- #
def test_elastic_diverges_per_worker_but_not_monolithic():
    pw = run(ELASTIC, "per_worker")
    assert pw.failures > 0 and min(pw.active_counts) < M
    ref_spec = ELASTIC.with_(fail_rate=0.0, elastic=False)
    # old replay: membership changed the price, never the math
    mono = run(ELASTIC, "monolithic")
    mono_ref = run(ref_spec, "monolithic")
    assert mono.losses == mono_ref.losses
    # per-worker replay: the shrunken membership genuinely changes the
    # trajectory relative to the full-W run
    pw_ref = run(ref_spec, "per_worker")
    assert pw.losses != pw_ref.losses
    assert not all(bool(jnp.array_equal(a, b))
                   for a, b in zip(jax.tree.leaves(pw.params),
                                   jax.tree.leaves(pw_ref.params)))


def test_elastic_live_w_collective_prices_actual_payload():
    """A ZO round with k live workers gathers exactly k scalars (4k bytes);
    the monolithic replay keeps booking the full in-program m."""
    pw = run(ELASTIC, "per_worker")
    mono = run(ELASTIC, "monolithic")
    shrunk = [(i, k) for i, (k, o) in
              enumerate(zip(pw.active_counts, pw.orders))
              if k < M and o == 0]
    assert shrunk, "elastic spec failed to shrink membership on a ZO round"
    for i, k in shrunk:
        assert pw.comm_bytes[i] == 4 * k
    i, k = shrunk[0]
    assert mono.comm_bytes[i] == 4 * M      # the old replay's full-m booking


def test_elastic_per_worker_replay_is_deterministic():
    r1, r2 = run(ELASTIC, "per_worker"), run(ELASTIC, "per_worker")
    assert r1.trace == r2.trace and r1.losses == r2.losses
    for a, b in zip(jax.tree.leaves(r1.params), jax.tree.leaves(r2.params)):
        assert jnp.array_equal(a, b)


# --------------------------------------------------------------------------- #
# faithful QSGD through the sim: nbytes × live workers
# --------------------------------------------------------------------------- #
def test_sim_fo_codec_books_nbytes_times_workers():
    codec = qsgd(4)
    pw = run(BASE, "per_worker", codec=codec, compress_mode="per_worker")
    legacy = run(BASE, "per_worker", codec=codec, compress_mode="legacy")
    fo_pw = [b for b, o in zip(pw.comm_bytes, pw.orders) if o == 1]
    fo_lg = [b for b, o in zip(legacy.comm_bytes, legacy.orders) if o == 1]
    assert fo_pw and set(fo_pw) == {codec.nbytes(D) * M}
    assert set(fo_lg) == {codec.nbytes(D)}
    # ZO rounds never compressed in either mode
    assert all(b == 4 * M for b, o in zip(pw.comm_bytes, pw.orders) if o == 0)


def test_qsgd_baseline_books_nbytes_times_workers():
    pw = run(BASE, "per_worker", which="qsgd")
    legacy = run(BASE, "per_worker", which="qsgd", compress_mode="legacy")
    assert set(pw.comm_bytes) == {qsgd(8).nbytes(D) * M}
    assert set(legacy.comm_bytes) == {qsgd(8).nbytes(D)}
