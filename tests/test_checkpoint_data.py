"""Checkpointing roundtrips + data pipeline determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore, save
from repro.data import (
    batches, make_classification, make_digits, parse_libsvm, token_batches,
)


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "b": {"c": jnp.ones((5,), jnp.int32), "d": jnp.zeros((2, 2), jnp.bfloat16)},
    }
    path = save(str(tmp_path), 7, tree)
    assert os.path.isdir(path)
    got, step = restore(str(tmp_path), tree)
    assert step == 7
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_checkpoint_latest_and_overwrite(tmp_path):
    tree = {"x": jnp.ones((3,))}
    save(str(tmp_path), 1, tree)
    save(str(tmp_path), 5, tree)
    assert latest_step(str(tmp_path)) == 5
    save(str(tmp_path), 5, {"x": jnp.full((3,), 2.0)})  # atomic overwrite
    got, _ = restore(str(tmp_path), tree)
    np.testing.assert_array_equal(np.asarray(got["x"]), 2.0)


def test_checkpoint_structure_mismatch_raises(tmp_path):
    save(str(tmp_path), 0, {"x": jnp.ones((3,))})
    with pytest.raises(ValueError, match="mismatch"):
        restore(str(tmp_path), {"y": jnp.ones((3,))})


# --------------------------------------------------------------------------- #
def test_classification_datasets():
    for name in ("sensorless", "acoustic", "covtype", "seismic"):
        ds = make_classification(name, n_train=512, n_test=128)
        from repro.data import DATASET_SPECS
        d, c = DATASET_SPECS[name]
        assert ds.x_train.shape == (512, d)
        assert ds.n_classes == c
        assert set(np.unique(ds.y_train)) <= set(range(c))
        # standardized features
        assert abs(ds.x_train.mean()) < 0.1
    # determinism
    a = make_classification("acoustic", n_train=64, n_test=16)
    b = make_classification("acoustic", n_train=64, n_test=16)
    np.testing.assert_array_equal(a.x_train, b.x_train)


def test_batches_iterator():
    ds = make_classification("seismic", n_train=256, n_test=64)
    it = batches(ds, 32, seed=3)
    b1, b2 = next(it), next(it)
    assert b1["x"].shape == (32, ds.n_features)
    assert not np.array_equal(b1["x"], b2["x"])


def test_token_batches_labels_are_shifted():
    it = token_batches(vocab=100, batch=4, seq=16, seed=0)
    b = next(it)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    assert (b["labels"][:, -1] == -1).all()
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 100


def test_digits_surrogate_dimensions():
    x, y = make_digits(n=128)
    assert x.shape == (128, 900)  # the paper's attack dimension d = 900
    assert x.min() >= -0.5 and x.max() <= 0.5
    assert len(np.unique(y)) > 3


def test_libsvm_parser(tmp_path):
    f = tmp_path / "toy.train"
    f.write_text("1 1:0.5 3:2.0\n2 2:-1.0\n1 1:1.5 2:0.25 3:-0.5\n")
    x, y = parse_libsvm(str(f))
    assert x.shape == (3, 3)
    np.testing.assert_allclose(x[0], [0.5, 0.0, 2.0])
    np.testing.assert_array_equal(y, [0, 1, 0])  # remapped to 0..C-1
