"""Checkpointing roundtrips + data pipeline determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore, save
from repro.data import (
    batches, make_classification, make_digits, parse_libsvm, token_batches,
)


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "b": {"c": jnp.ones((5,), jnp.int32), "d": jnp.zeros((2, 2), jnp.bfloat16)},
    }
    path = save(str(tmp_path), 7, tree)
    assert os.path.isdir(path)
    got, step = restore(str(tmp_path), tree)
    assert step == 7
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_checkpoint_latest_and_overwrite(tmp_path):
    tree = {"x": jnp.ones((3,))}
    save(str(tmp_path), 1, tree)
    save(str(tmp_path), 5, tree)
    assert latest_step(str(tmp_path)) == 5
    save(str(tmp_path), 5, {"x": jnp.full((3,), 2.0)})  # atomic overwrite
    got, _ = restore(str(tmp_path), tree)
    np.testing.assert_array_equal(np.asarray(got["x"]), 2.0)


def test_checkpoint_structure_mismatch_raises(tmp_path):
    save(str(tmp_path), 0, {"x": jnp.ones((3,))})
    with pytest.raises(ValueError, match="mismatch"):
        restore(str(tmp_path), {"y": jnp.ones((3,))})


# --------------------------------------------------------------------------- #
def test_classification_datasets():
    for name in ("sensorless", "acoustic", "covtype", "seismic"):
        ds = make_classification(name, n_train=512, n_test=128)
        from repro.data import DATASET_SPECS
        d, c = DATASET_SPECS[name]
        assert ds.x_train.shape == (512, d)
        assert ds.n_classes == c
        assert set(np.unique(ds.y_train)) <= set(range(c))
        # standardized features
        assert abs(ds.x_train.mean()) < 0.1
    # determinism
    a = make_classification("acoustic", n_train=64, n_test=16)
    b = make_classification("acoustic", n_train=64, n_test=16)
    np.testing.assert_array_equal(a.x_train, b.x_train)


def test_batches_iterator():
    ds = make_classification("seismic", n_train=256, n_test=64)
    it = batches(ds, 32, seed=3)
    b1, b2 = next(it), next(it)
    assert b1["x"].shape == (32, ds.n_features)
    assert not np.array_equal(b1["x"], b2["x"])


def test_token_batches_labels_are_shifted():
    it = token_batches(vocab=100, batch=4, seq=16, seed=0)
    b = next(it)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    assert (b["labels"][:, -1] == -1).all()
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 100


def test_digits_surrogate_dimensions():
    x, y = make_digits(n=128)
    assert x.shape == (128, 900)  # the paper's attack dimension d = 900
    assert x.min() >= -0.5 and x.max() <= 0.5
    assert len(np.unique(y)) > 3


def test_libsvm_parser(tmp_path):
    f = tmp_path / "toy.train"
    f.write_text("1 1:0.5 3:2.0\n2 2:-1.0\n1 1:1.5 2:0.25 3:-0.5\n")
    x, y = parse_libsvm(str(f))
    assert x.shape == (3, 3)
    np.testing.assert_allclose(x[0], [0.5, 0.0, 2.0])
    np.testing.assert_array_equal(y, [0, 1, 0])  # remapped to 0..C-1


# --------------------------------------------------------------------------- #
# method-STATE round-tripping: the sim's failure injection restores optimizer
# state and the adaptive-tau counter from checkpoints, so a lossy round-trip
# would silently corrupt simulated runs (and real resumes)
# --------------------------------------------------------------------------- #
def _ckpt_quad_loss(params, batch):
    import jax.numpy as jnp
    return 0.5 * jnp.mean(jnp.sum((params["x"] - batch["t"]) ** 2, -1))


def test_checkpoint_method_state_roundtrip_adaptive(tmp_path):
    """Interrupt adaptive HO-SGD mid-schedule; the restored replica must
    continue bit-identically (params, momentum AND since_fo counter)."""
    import jax.numpy as jnp
    from repro.core.ho_sgd import HOSGDConfig, make_adaptive_ho_sgd
    from repro.opt.optimizers import const_schedule, sgd

    cfg = HOSGDConfig(tau=4, mu=1e-3, m=2, lr=0.1, zo_lr=0.01, momentum=0.9)
    meth = make_adaptive_ho_sgd(
        _ckpt_quad_loss, cfg, tau_schedule=lambda t: 2 + t // 2,
        opt=sgd(const_schedule(cfg.lr), cfg.momentum))
    params = {"x": jnp.zeros((16,), jnp.float32)}
    batch = {"t": jnp.ones((4, 16), jnp.float32)}

    state = meth.init(params)
    for t in range(3):                      # stop mid-period: since_fo != 0
        params, state, _ = meth.step(t, params, state, batch)
    assert int(state["since_fo"]) > 0
    save(str(tmp_path), 3, {"params": params, "state": state})

    restored, step = restore(str(tmp_path), {"params": params, "state": state})
    assert step == 3
    assert int(restored["state"]["since_fo"]) == int(state["since_fo"])

    # momentum buffers restored exactly
    for a, b in zip(jax.tree.leaves(state["base"]),
                    jax.tree.leaves(restored["state"]["base"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert jnp.asarray(a).dtype == jnp.asarray(b).dtype

    # continuing from the restored replica is bit-identical to the live run
    live_p, live_s = params, state
    rest_p, rest_s = restored["params"], restored["state"]
    for t in range(3, 6):
        live_p, live_s, live_m = meth.step(t, live_p, live_s, batch)
        rest_p, rest_s, rest_m = meth.step(t, rest_p, rest_s, batch)
        assert int(live_m["order"]) == int(rest_m["order"])
    for a, b in zip(jax.tree.leaves(live_p), jax.tree.leaves(rest_p)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(live_s["since_fo"]) == int(rest_s["since_fo"])


def test_elastic_rejoin_checkpoint_roundtrip_bit_exact(tmp_path):
    """Elastic cluster: a worker fails mid-tau-window (during the ZO
    iterations between FO syncs), rejoins through a REAL repro.checkpoint
    round-trip, and the continued run matches a never-failed run's params
    AND method state bit-for-bit at the next FO sync — a lossy round-trip
    (dtype width, python-scalar counters) would show up as divergence.

    Runs under ``replay="monolithic"``, the mode whose contract is that
    membership changes pricing only: any divergence can then ONLY come from
    the checkpoint round-trip.  (The default per-worker replay diverges by
    design — the live workers' shards change the math; see
    tests/test_replay_fidelity.py.)"""
    import jax.numpy as jnp
    from repro.sim import ClusterSpec, compute_model_for, make_sim_methods, \
        simulate

    def quad(params, batch):
        return 0.5 * jnp.mean(jnp.sum((params["x"] - batch["t"]) ** 2, -1))

    params = {"x": jnp.zeros((32,), jnp.float32)}
    batch = {"t": jnp.ones((8, 32), jnp.float32)}

    def batches():
        while True:
            yield batch

    def run(spec, n):
        sm = make_sim_methods(quad, params, spec, tau=4, lr=0.1, zo_lr=0.05,
                              which=["ho_sgd"])["ho_sgd"]
        return simulate(sm, params, batches(), spec, n,
                        compute=compute_model_for(params, spec, 2),
                        ckpt_dir=str(tmp_path), replay="monolithic")

    # seed 1 is pinned: exactly one worker leaves during ZO iteration t=1
    # (mid-tau-window for tau=4: FO at t=0, next FO sync at t=4) and
    # rejoins before that sync
    spec = ClusterSpec(m=4, flops_per_sec=1e9, bandwidth=1e6, seed=1,
                       elastic=True, fail_rate=4000.0, downtime=1e-4,
                       restart_time=1e-5)
    n = 5                                     # last committed step: FO @ t=4
    res = run(spec, n)
    assert res.failures == 1 and res.rejoins == 1
    assert res.orders[4] == 1                 # the next FO sync committed
    assert min(res.active_counts[1:4]) < 4    # W shrank inside the window
    assert res.active_counts[4] == 4          # ...and regrew by the sync
    kinds = [k for _, k, _ in res.trace]
    assert "leave" in kinds and "rejoin" in kinds and "restore" in kinds

    ref = run(spec.with_(fail_rate=0.0, elastic=False), n)
    assert ref.failures == 0
    for a, b in zip(jax.tree.leaves(res.params), jax.tree.leaves(ref.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert jnp.asarray(a).dtype == jnp.asarray(b).dtype
    # method state too: optimizer tree and the since-FO schedule counter
    assert int(res.state["since_fo"]) == int(ref.state["since_fo"])
    for a, b in zip(jax.tree.leaves(res.state["opt"]),
                    jax.tree.leaves(ref.state["opt"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_python_scalar_leaves(tmp_path):
    """Python int/float leaves (schedule counters) survive save/restore
    EXACTLY — including non-fp32-representable floats and ints >= 2**31
    (they ride as 64-bit numpy, never through jax's x64-disabled default)."""
    tree = {"w": jnp.ones((3,), jnp.float32), "since_fo": 5, "lr": 0.1,
            "tokens_seen": 2**40 + 3}
    save(str(tmp_path), 0, tree)
    got, _ = restore(str(tmp_path), tree)
    assert int(got["since_fo"]) == 5
    assert float(got["lr"]) == 0.1
    assert int(got["tokens_seen"]) == 2**40 + 3
    np.testing.assert_array_equal(np.asarray(got["w"]), 1.0)
    assert got["w"].dtype == jnp.float32
