"""The simulator's contract with the cost models: the analytic per-method
communication model (``Method.comm_scalars`` / ``MeterRegistry``) and the
``CommLedger``-measured bytes must agree across the tau spectrum and the
whole codec zoo — the sim prices iterations off the ledger, so a divergence
here silently corrupts every simulated wall-clock number."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import HOSGDConfig, make_ho_sgd
from repro.core.distributed import make_distributed_ho_sgd
from repro.dist import CommLedger, get_compressor
from repro.launch.mesh import make_test_mesh
from repro.metrics import MeterRegistry, comm_report
from repro.opt.optimizers import const_schedule, sgd


def quad_loss(params, batch):
    return 0.5 * jnp.mean(jnp.sum((params["x"] - batch["t"]) ** 2, -1))


D, M = 512, 1          # single-worker mesh: send and receive conventions agree


def drive_ledger(tau: int, codec=None, periods: int = 2):
    mesh = make_test_mesh(data=1, model=1)
    ho = HOSGDConfig(tau=tau, mu=1e-3, m=M, lr=0.05, zo_lr=0.05 / D)
    opt = sgd(const_schedule(ho.lr))
    fo, zo = make_distributed_ho_sgd(quad_loss, mesh, ho, opt,
                                     compressor=codec)
    ledger = CommLedger()
    fo_j = ledger.wrap("fo", jax.jit(fo))
    zo_j = ledger.wrap("zo", jax.jit(zo))
    params = {"x": jnp.zeros((D,), jnp.float32)}
    state = opt.init(params)
    batch = {"t": jnp.ones((4, D), jnp.float32)}
    for t in range(periods * tau):
        step = fo_j if t % tau == 0 else zo_j
        params, state, _ = step(jnp.int32(t), params, state, batch)
    return ledger


@pytest.mark.parametrize("tau", [1, 2, 8])
def test_method_comm_scalars_agree_with_ledger(tau):
    """4 * Method.comm_scalars(d) == measured amortized bytes/iteration."""
    ledger = drive_ledger(tau)
    meth = make_ho_sgd(quad_loss, HOSGDConfig(tau=tau, m=M, lr=0.05))
    iters = sum(ledger.steps.values())
    measured = ledger.total_bytes() / iters
    assert measured == pytest.approx(4.0 * meth.comm_scalars(D))
    assert ledger.bytes_per_step("fo") == 4 * D
    if tau > 1:
        assert ledger.bytes_per_step("zo") == 4 * M


@pytest.mark.parametrize("tau", [1, 2, 8])
def test_meter_registry_agrees_with_ledger(tau):
    """MeterRegistry's analytic accumulation == the ledger's total bytes."""
    ledger = drive_ledger(tau)
    meth = make_ho_sgd(quad_loss, HOSGDConfig(tau=tau, m=M, lr=0.05))
    reg = MeterRegistry(D)
    iters = sum(ledger.steps.values())
    reg.tick(meth, iters)
    assert 4.0 * reg.scalars_sent == pytest.approx(ledger.total_bytes())


@pytest.mark.parametrize("tau", [1, 2, 8])
@pytest.mark.parametrize("codec_name", ["qsgd", "signsgd", "topk"])
def test_codec_wire_estimates_agree_with_ledger(tau, codec_name):
    """Compressed FO steps book exactly the codec's nbytes wire model —
    what the sim charges for a compressed exchange."""
    codec = get_compressor(codec_name)
    ledger = drive_ledger(tau, codec=codec)
    assert ledger.bytes_per_step("fo") == codec.nbytes(D)
    if tau > 1:
        assert ledger.bytes_per_step("zo") == 4 * M    # ZO never compressed
    # comm_report's analytic column uses the same per-leaf wire model
    lines = comm_report(ledger, d=D, m=M, tau=tau, codec=codec,
                        leaf_dims=[D])
    fo_line = next(l for l in lines if "fo_bytes_per_step" in l)
    measured, analytic = (int(part.split("=")[1])
                          for part in fo_line.split(",")[1:3])
    assert measured == analytic


def test_csvlogger_context_manager_closes_on_exception(tmp_path):
    """launch.train / launch.sim hold the log open for the whole run — the
    handle must be released even when the loop raises."""
    from repro.metrics import CSVLogger

    path = str(tmp_path / "log.csv")
    with pytest.raises(RuntimeError):
        with CSVLogger(path, ["step", "loss"]) as logger:
            logger.log(step=0, loss=1.0)
            raise RuntimeError("mid-run failure")
    assert logger._fh is None                       # closed, not leaked
    with open(path) as f:
        lines = f.read().strip().splitlines()
    assert lines == ["step,loss", "0,1.0"]
    logger.close()                                  # idempotent

    with CSVLogger(None, ["a"]) as nolog:           # disabled logger: no-op
        nolog.log(a=1)
