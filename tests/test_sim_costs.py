"""The simulator's contract with the cost models: the analytic per-method
communication model (``Method.comm_scalars`` / ``MeterRegistry``) and the
``CommLedger``-measured bytes must agree across the tau spectrum and the
whole codec zoo — the sim prices iterations off the ledger, so a divergence
here silently corrupts every simulated wall-clock number."""
import math

import jax
import jax.numpy as jnp
import pytest

from repro.core import HOSGDConfig, make_ho_sgd
from repro.core.distributed import make_distributed_ho_sgd
from repro.dist import CommLedger, get_compressor
from repro.launch.mesh import make_test_mesh
from repro.metrics import MeterRegistry, comm_report
from repro.opt.optimizers import const_schedule, sgd
from repro.sim import (
    ClusterSpec,
    CollectiveModel,
    LinkContention,
    LinkModel,
    SharedLink,
    Topology,
    compute_model_for,
    exposed_comm_time,
    make_sim_methods,
    overlapped_step_time,
    simulate,
)


def quad_loss(params, batch):
    return 0.5 * jnp.mean(jnp.sum((params["x"] - batch["t"]) ** 2, -1))


D, M = 512, 1          # single-worker mesh: send and receive conventions agree


def drive_ledger(tau: int, codec=None, periods: int = 2):
    mesh = make_test_mesh(data=1, model=1)
    ho = HOSGDConfig(tau=tau, mu=1e-3, m=M, lr=0.05, zo_lr=0.05 / D)
    opt = sgd(const_schedule(ho.lr))
    fo, zo = make_distributed_ho_sgd(quad_loss, mesh, ho, opt,
                                     compressor=codec)
    ledger = CommLedger()
    fo_j = ledger.wrap("fo", jax.jit(fo))
    zo_j = ledger.wrap("zo", jax.jit(zo))
    params = {"x": jnp.zeros((D,), jnp.float32)}
    state = opt.init(params)
    batch = {"t": jnp.ones((4, D), jnp.float32)}
    for t in range(periods * tau):
        step = fo_j if t % tau == 0 else zo_j
        params, state, _ = step(jnp.int32(t), params, state, batch)
    return ledger


@pytest.mark.parametrize("tau", [1, 2, 8])
def test_method_comm_scalars_agree_with_ledger(tau):
    """4 * Method.comm_scalars(d) == measured amortized bytes/iteration."""
    ledger = drive_ledger(tau)
    meth = make_ho_sgd(quad_loss, HOSGDConfig(tau=tau, m=M, lr=0.05))
    iters = sum(ledger.steps.values())
    measured = ledger.total_bytes() / iters
    assert measured == pytest.approx(4.0 * meth.comm_scalars(D))
    assert ledger.bytes_per_step("fo") == 4 * D
    if tau > 1:
        assert ledger.bytes_per_step("zo") == 4 * M


@pytest.mark.parametrize("tau", [1, 2, 8])
def test_meter_registry_agrees_with_ledger(tau):
    """MeterRegistry's analytic accumulation == the ledger's total bytes."""
    ledger = drive_ledger(tau)
    meth = make_ho_sgd(quad_loss, HOSGDConfig(tau=tau, m=M, lr=0.05))
    reg = MeterRegistry(D)
    iters = sum(ledger.steps.values())
    reg.tick(meth, iters)
    assert 4.0 * reg.scalars_sent == pytest.approx(ledger.total_bytes())


@pytest.mark.parametrize("tau", [1, 2, 8])
@pytest.mark.parametrize("codec_name", ["qsgd", "signsgd", "topk"])
def test_codec_wire_estimates_agree_with_ledger(tau, codec_name):
    """Compressed FO steps book exactly the codec's nbytes wire model —
    what the sim charges for a compressed exchange."""
    codec = get_compressor(codec_name)
    ledger = drive_ledger(tau, codec=codec)
    assert ledger.bytes_per_step("fo") == codec.nbytes(D)
    if tau > 1:
        assert ledger.bytes_per_step("zo") == 4 * M    # ZO never compressed
    # comm_report's analytic column uses the same per-leaf wire model
    lines = comm_report(ledger, d=D, m=M, tau=tau, codec=codec,
                        leaf_dims=[D])
    fo_line = next(l for l in lines if "fo_bytes_per_step" in l)
    measured, analytic = (int(part.split("=")[1])
                          for part in fo_line.split(",")[1:3])
    assert measured == analytic


# --------------------------------------------------------------------------- #
# CollectiveModel: the ring/tree/hierarchical all-reduce times must match the
# closed-form alpha-beta expressions, and simulated runs must still price
# every iteration at the CommLedger-booked bytes (never re-derived) no matter
# which topology does the pricing.
# --------------------------------------------------------------------------- #
ALPHA, BETA, NBYTES = 2e-4, 1e-6, 4096.0
LINK = LinkModel(alpha=ALPHA, beta=BETA)


@pytest.mark.parametrize("w", [2, 4, 8])
def test_ring_all_reduce_closed_form(w):
    cm = CollectiveModel(link=LINK, kind="ring")
    expect = 2 * (w - 1) * ALPHA + (2 * (w - 1) / w) * NBYTES * BETA
    assert cm.all_reduce_time(NBYTES, w) == pytest.approx(expect)


@pytest.mark.parametrize("w", [2, 4, 8])
def test_tree_all_reduce_closed_form(w):
    cm = CollectiveModel(link=LINK, kind="tree")
    rounds = 2 * math.ceil(math.log2(w))
    expect = rounds * (ALPHA + NBYTES * BETA)
    assert cm.all_reduce_time(NBYTES, w) == pytest.approx(expect)


@pytest.mark.parametrize("w", [2, 4, 8])
def test_flat_all_reduce_is_the_pr3_link_model(w):
    cm = CollectiveModel(link=LINK, kind="flat")
    assert cm.all_reduce_time(NBYTES, w) == pytest.approx(LINK.time(NBYTES))


@pytest.mark.parametrize("w,pods", [(4, 2), (8, 2), (8, 4)])
def test_hierarchical_all_reduce_closed_form(w, pods):
    """Intra-pod ring over w/pods workers on the fast link + inter-pod ring
    over pods on the slow link."""
    inter = LinkModel(alpha=5e-3, beta=1e-5)
    cm = CollectiveModel(link=LINK, kind="ring", pods=pods, inter_link=inter)
    wpp = w // pods
    intra = (2 * (wpp - 1) * ALPHA + (2 * (wpp - 1) / wpp) * NBYTES * BETA
             if wpp > 1 else 0.0)
    ixp = (2 * (pods - 1) * inter.alpha
           + (2 * (pods - 1) / pods) * NBYTES * inter.beta)
    assert cm.all_reduce_time(NBYTES, w) == pytest.approx(intra + ixp)


@pytest.mark.parametrize("w", [2, 4, 8])
def test_gossip_exchange_closed_form(w):
    """Ring-gossip round (the round IR's neighbor_exchange): min(2, w-1)
    sequential neighbor transfers of the full payload — independent of the
    ring length beyond the two-neighbor degree."""
    cm = CollectiveModel(link=LINK, kind="gossip")
    k = min(2, w - 1)
    expect = k * (ALPHA + NBYTES * BETA)
    assert cm.all_reduce_time(NBYTES, w) == pytest.approx(expect)


def test_collective_degenerate_cases():
    cm = CollectiveModel(link=LINK, kind="ring")
    assert cm.all_reduce_time(NBYTES, 1) == 0.0    # one worker: no exchange
    assert cm.all_reduce_time(0, 8) == 0.0         # no bytes: no time
    gm = CollectiveModel(link=LINK, kind="gossip")
    assert gm.all_reduce_time(NBYTES, 1) == 0.0


def _sim_quad(spec, n_iters=8, tau=4, overlap=1):
    def quad(params, batch):
        return 0.5 * jnp.mean(jnp.sum((params["x"] - batch["t"]) ** 2, -1))

    params = {"x": jnp.zeros((64,), jnp.float32)}
    batch = {"t": jnp.ones((8, 64), jnp.float32)}

    def batches():
        while True:
            yield batch

    sm = make_sim_methods(quad, params, spec, tau=tau, lr=0.1, zo_lr=0.05,
                          which=["ho_sgd"], overlap_buckets=overlap)["ho_sgd"]
    return simulate(sm, params, batches(), spec, n_iters,
                    compute=compute_model_for(params, spec, 2))


@pytest.mark.parametrize("spec_kw", [
    dict(collective="ring"),
    dict(collective="tree"),
    dict(collective="gossip"),
    dict(collective="ring",
         topology=Topology(pods=2, inter_alpha=1e-3, inter_bandwidth=1e5)),
])
def test_sim_bytes_stay_ledger_booked_under_topologies(spec_kw):
    """Changing the collective changes TIME, never BYTES: every topology
    prices the exact bytes the replayed programs booked (FO = 4d, ZO = 4m),
    and the simulated comm seconds equal the closed-form collective time at
    those booked byte counts."""
    d, m = 64, 4
    spec = ClusterSpec(m=m, flops_per_sec=1e9, bandwidth=1e6, seed=0,
                       **spec_kw)
    res = _sim_quad(spec)
    # 2 FO steps book 4*d each; 6 ZO steps book 4*m each — identical to the
    # flat-topology pin in test_sim.py
    assert res.bytes_total == 2 * 4 * d + 6 * 4 * m
    expect_comm = sum(spec.collective_time(b, m) for b in res.comm_bytes)
    assert res.comm_s == pytest.approx(expect_comm)


# --------------------------------------------------------------------------- #
# Overlap-aware pricing: the exposed-comm closed form must match
# max(0, comm - compute*(B-1)/B) per collective kind, degenerate to the
# strict price at B=1, and the simulator must price whole runs off exactly
# this formula while booking bit-identical bytes overlap on vs off.
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("kind", ["flat", "ring", "tree"])
@pytest.mark.parametrize("w", [2, 4, 8])
def test_exposed_comm_closed_form(kind, w):
    cm = CollectiveModel(link=LINK, kind=kind)
    comm = cm.all_reduce_time(NBYTES, w)
    assert comm > 0.0
    # B=1 degenerates to the strict compute-then-communicate price
    assert exposed_comm_time(cm, NBYTES, w, 1, comm) == pytest.approx(comm)
    # partial hiding: only (B-1)/B of the compute can cover traffic
    for B in (2, 4, 8):
        compute_s = comm            # compute exactly as long as the exchange
        expect = comm - compute_s * (B - 1) / B
        assert exposed_comm_time(cm, NBYTES, w, B, compute_s) \
            == pytest.approx(expect)
        assert overlapped_step_time(cm, NBYTES, w, B, compute_s) \
            == pytest.approx(compute_s + expect)
    # enough compute hides everything; exposure never goes negative
    assert exposed_comm_time(cm, NBYTES, w, 4, 100.0 * comm) == 0.0
    # no bytes: nothing to expose regardless of bucketing
    assert exposed_comm_time(cm, 0, w, 4, 1.0) == 0.0


def test_shared_link_two_transfer_sharing():
    """The README contention pin: two transfers of duration g both ready at
    T complete at T+g and T+2g (FIFO serialization); after the link idles,
    a later transfer starts unimpeded; zero durations pass through."""
    g, T = 0.25, 10.0
    link = SharedLink()
    assert link.acquire(T, g) == pytest.approx(T + g)
    assert link.acquire(T, g) == pytest.approx(T + 2 * g)
    assert link.acquire(T + 5.0, g) == pytest.approx(T + 5.0 + g)
    assert link.acquire(0.0, 0.0) == 0.0          # no reservation
    assert link.free_at == pytest.approx(T + 5.0 + g)


def test_link_contention_routes_pod_then_inter():
    """2-pod, 4-worker routing: same-pod transfers serialize on their pod
    link, cross-pod components serialize on the single inter link."""
    lc = LinkContention(m=4, pods=2)
    assert [lc.pod_of(w) for w in range(4)] == [0, 0, 1, 1]
    # workers 0 and 1 share pod link 0: intra components serialize
    assert lc.transfer(0, 0.0, intra_s=1.0) == pytest.approx(1.0)
    assert lc.transfer(1, 0.0, intra_s=1.0) == pytest.approx(2.0)
    # worker 2 is on pod link 1 — no intra contention with pod 0 — but its
    # inter component queues behind nothing yet
    assert lc.transfer(2, 0.0, intra_s=1.0, inter_s=0.5) == pytest.approx(1.5)
    # worker 3 contends on BOTH: pod link 1 busy until 2.0, inter until 1.5
    assert lc.transfer(3, 0.0, intra_s=1.0, inter_s=0.5) == pytest.approx(2.5)
    # clone/adopt: tentative planning never leaks into the real state
    trial = lc.clone()
    trial.transfer(0, 10.0, intra_s=1.0)
    assert lc.pod_links[0].free_at == pytest.approx(2.0)
    lc.adopt(trial)
    assert lc.pod_links[0].free_at == pytest.approx(11.0)


@pytest.mark.parametrize("kind", ["ring", "tree"])
@pytest.mark.parametrize("buckets", [2, 8])
def test_sim_overlap_prices_exposed_comm_and_keeps_bytes(kind, buckets):
    """End-to-end: an overlapped run's comm seconds equal the closed-form
    exposed time summed over the replayed rounds (FO books 4d, ZO 4m; each
    round's overlappable compute is ITS OWN critical-path dt), while every
    byte count stays bit-identical to the strict B=1 run."""
    d, m = 64, 4
    spec = ClusterSpec(m=m, flops_per_sec=1e9, bandwidth=1e6, seed=0,
                       collective=kind)
    strict = _sim_quad(spec, overlap=1)
    res = _sim_quad(spec, overlap=buckets)
    assert res.bytes_total == strict.bytes_total
    assert res.comm_bytes == strict.comm_bytes
    # per-round closed form: 2 FO rounds (geval: 3x fwd flops), 6 ZO rounds
    # (2 fevals), fwd = 2*d*per_worker_batch FLOPs on every worker
    cm = spec.collective_model
    fwd = 2.0 * d * 2
    dt_fo = 3.0 * fwd / spec.flops_per_sec
    dt_zo = 2.0 * fwd / spec.flops_per_sec
    expect = (2 * exposed_comm_time(cm, 4 * d, m, buckets, dt_fo)
              + 6 * exposed_comm_time(cm, 4 * m, m, buckets, dt_zo))
    assert res.comm_s == pytest.approx(expect)
    assert res.comm_s < strict.comm_s       # overlap strictly helps here
    assert res.compute_s == pytest.approx(strict.compute_s)
    assert res.losses == strict.losses      # pricing only, math untouched


def test_csvlogger_context_manager_closes_on_exception(tmp_path):
    """launch.train / launch.sim hold the log open for the whole run — the
    handle must be released even when the loop raises."""
    from repro.metrics import CSVLogger

    path = str(tmp_path / "log.csv")
    with pytest.raises(RuntimeError):
        with CSVLogger(path, ["step", "loss"]) as logger:
            logger.log(step=0, loss=1.0)
            raise RuntimeError("mid-run failure")
    assert logger._fh is None                       # closed, not leaked
    with open(path) as f:
        lines = f.read().strip().splitlines()
    assert lines == ["step,loss", "0,1.0"]
    logger.close()                                  # idempotent

    with CSVLogger(None, ["a"]) as nolog:           # disabled logger: no-op
        nolog.log(a=1)
