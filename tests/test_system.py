"""End-to-end behaviour tests for the paper's system.

The headline behavioural claims, executed for real (reduced scale):
  1. HO-SGD trains a non-convex model to high accuracy.
  2. Per-iteration communication matches the paper's accounting:
     (tau-1+d)/tau scalars per worker vs d for syncSGD.
  3. The full substrate composes: config -> model -> optimizer ->
     checkpoint -> restore -> serving.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore, save
from repro.configs import get_config
from repro.core import HOSGDConfig, make_ho_sgd, make_sync_sgd, run_method
from repro.data import batches, make_classification
from repro.metrics import MeterRegistry
from repro.models import transformer as T
from repro.models.mlp import init_mlp_classifier, mlp_accuracy, mlp_loss
from repro.serving import Engine, ServeConfig


def test_ho_sgd_trains_classifier_end_to_end():
    m, B, tau = 4, 32, 8
    ds = make_classification("acoustic", n_train=4096, n_test=1024)
    params = init_mlp_classifier(jax.random.key(0), ds.n_features,
                                 ds.n_classes, hidden=96)
    d = sum(x.size for x in jax.tree.leaves(params))
    meth = make_ho_sgd(mlp_loss, HOSGDConfig(
        tau=tau, mu=1e-3, m=m, lr=0.1, zo_lr=0.1 * 30 / d))
    meter = MeterRegistry(d)
    hist = run_method(meth, params, batches(ds, m * B, seed=1), 120)
    meter.tick(meth, iters=120)
    acc = float(mlp_accuracy(hist["params"], {"x": ds.x_test, "y": ds.y_test}))
    assert acc > 0.85, acc

    # communication accounting (claim 2): HO-SGD sent ~tau-fold fewer scalars
    sync = make_sync_sgd(mlp_loss, m, lr=0.1)
    sync_meter = MeterRegistry(d)
    sync_meter.tick(sync, iters=120)
    ratio = (sync_meter.summary()["scalars_sent_per_worker"]
             / meter.summary()["scalars_sent_per_worker"])
    assert abs(ratio - tau / (1 + (tau - 1) / d)) / ratio < 1e-3


def test_transformer_train_checkpoint_serve_roundtrip(tmp_path):
    """config -> train steps -> checkpoint -> restore -> generate."""
    cfg = get_config("gemma2-2b").reduced().with_(remat=False)
    params = T.init_model(jax.random.key(1), cfg)
    loss_fn = lambda p, b: T.loss_fn(cfg, p, b)
    d = sum(x.size for x in jax.tree.leaves(params))
    meth = make_ho_sgd(loss_fn, HOSGDConfig(
        tau=3, mu=1e-3, m=2, lr=0.05, zo_lr=0.05 / d))
    rng = np.random.default_rng(0)

    def lm_batches():
        while True:
            toks = rng.integers(0, cfg.vocab_size, (4, 16)).astype(np.int32)
            labels = np.concatenate([toks[:, 1:], -np.ones((4, 1), np.int32)], 1)
            yield {"tokens": toks, "labels": labels}

    hist = run_method(meth, params, lm_batches(), 7)
    assert np.isfinite(hist["loss"]).all()
    trained = hist["params"]

    save(str(tmp_path), 7, trained)
    restored, step = restore(str(tmp_path), trained)
    assert step == 7
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(trained)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))

    eng = Engine(cfg, restored, ServeConfig(max_seq=32))
    outs = eng.generate([[1, 2, 3], [4, 5, 6, 7]], max_new=4)
    assert [len(o) for o in outs] == [7, 8]
