"""Pre-shared-seed direction generation: determinism, stats, consistency."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import directions as D


def test_hash_deterministic():
    a = D.gaussian_from_salt((1000,), D.fold(1, 2, 3, 4))
    b = D.gaussian_from_salt((1000,), D.fold(1, 2, 3, 4))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    c = D.gaussian_from_salt((1000,), D.fold(1, 2, 3, 5))
    assert float(jnp.max(jnp.abs(a - c))) > 0.1


def test_gaussian_stats():
    g = D.gaussian_from_salt((200_000,), D.fold(7))
    assert abs(float(jnp.mean(g))) < 0.01
    assert abs(float(jnp.std(g)) - 1.0) < 0.01
    # third/fourth moments of N(0,1)
    assert abs(float(jnp.mean(g**3))) < 0.05
    assert abs(float(jnp.mean(g**4)) - 3.0) < 0.1


def test_offset_split_consistency():
    """Generating a leaf in two halves with offsets == generating it whole
    (this is what lets Pallas grid blocks agree with the jnp whole-tree gen)."""
    salt = D.fold(42)
    whole = D.gaussian_from_salt((512,), salt)
    lo = D.gaussian_from_salt((256,), salt, offset=0)
    hi = D.gaussian_from_salt((256,), salt, offset=256)
    np.testing.assert_array_equal(np.asarray(whole), np.concatenate([lo, hi]))


def test_sphere_direction_unit_norm():
    params = {"a": jnp.zeros((100, 7)), "b": {"c": jnp.zeros((333,))}}
    v = D.sphere_direction(params, seed=0, t=jnp.int32(3), worker=jnp.uint32(1))
    ssq = sum(float(jnp.sum(x**2)) for x in jax.tree.leaves(v))
    assert abs(ssq - 1.0) < 1e-5
    assert jax.tree.structure(v) == jax.tree.structure(params)


def test_workers_get_distinct_directions():
    params = {"a": jnp.zeros((64,))}
    vs = [
        np.asarray(D.sphere_direction(params, 0, jnp.int32(0), jnp.uint32(i))["a"])
        for i in range(4)
    ]
    for i in range(4):
        for j in range(i + 1, 4):
            cos = float(np.dot(vs[i], vs[j]))
            assert abs(cos) < 0.5, (i, j, cos)  # near-orthogonal in high dim


def test_iterations_get_distinct_directions():
    params = {"a": jnp.zeros((64,))}
    v0 = np.asarray(D.sphere_direction(params, 0, jnp.int32(0), jnp.uint32(0))["a"])
    v1 = np.asarray(D.sphere_direction(params, 0, jnp.int32(1), jnp.uint32(0))["a"])
    assert abs(float(np.dot(v0, v1))) < 0.5


def test_tree_dim_and_axpy():
    params = {"a": jnp.ones((3, 4)), "b": jnp.zeros((5,), jnp.float32)}
    assert D.tree_dim(params) == 17
    v = {"a": jnp.full((3, 4), 2.0), "b": jnp.ones((5,))}
    out = D.tree_axpy(0.5, v, params)
    np.testing.assert_allclose(np.asarray(out["a"]), 2.0)
    np.testing.assert_allclose(np.asarray(out["b"]), 0.5)
