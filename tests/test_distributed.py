"""Distribution tests that need >1 device run in subprocesses with their own
XLA_FLAGS (this process must stay single-device per the dry-run contract)."""
import os
import subprocess
import sys

import jax
import pytest

HERE = os.path.dirname(__file__)
SRC = os.path.join(HERE, "..", "src")


def _run(script_args, timeout=900, env_extra=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.update(env_extra or {})
    return subprocess.run(
        [sys.executable] + script_args, capture_output=True, text=True,
        timeout=timeout, env=env,
    )


def test_main_process_device_count_matches_contract():
    # the repo contract: the main process only has multiple devices when the
    # environment forces them (CI runs tier-1 with an 8-device XLA flag so
    # mesh-path tests see a real mesh); otherwise it stays single-device
    import re
    m = re.search(r"host_platform_device_count=(\d+)",
                  os.environ.get("XLA_FLAGS", ""))
    assert jax.device_count() == (int(m.group(1)) if m else 1)


@pytest.mark.slow
def test_distributed_matches_reference():
    r = _run([os.path.join(HERE, "helpers", "dist_check.py")])
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-4000:]
    assert "DIST_CHECK_OK" in r.stdout


@pytest.mark.slow
def test_comm_ledger_on_four_worker_mesh():
    """Table 1, measured: ZO books exactly 4*m bytes on a real 4-worker mesh,
    dense FO books 4*d, QSGD-compressed FO strictly less (ISSUE 1 criteria)."""
    r = _run([os.path.join(HERE, "helpers", "ledger_check.py")])
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-4000:]
    assert "LEDGER_CHECK_OK" in r.stdout


@pytest.mark.slow
@pytest.mark.parametrize("arch,shape,step", [
    ("gemma2-2b", "train_4k", "fo"),
    ("falcon-mamba-7b", "decode_32k", "decode"),
    ("hubert-xlarge", "prefill_32k", "prefill"),
    ("qwen3-moe-235b-a22b", "train_4k", "zo"),
])
def test_dryrun_lowers_on_small_mesh(arch, shape, step, tmp_path):
    """Full-size configs lower+compile on an 8-device (4x2 or 2x2x2) mesh —
    a scaled-down rehearsal of the production dry-run (the 512-device run is
    executed via `python -m repro.launch.dryrun --all`; see EXPERIMENTS.md)."""
    r = _run(
        ["-m", "repro.launch.dryrun", "--arch", arch, "--shape", shape,
         "--step", step, "--mesh", "pod", "--out", str(tmp_path),
         "--no-correct"],
        env_extra={"REPRO_DRYRUN_DEVICES": "8", "REPRO_TEST_MESH": "4x2"},
    )
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-4000:]
    assert "[ok]" in r.stdout or "[skip]" in r.stdout


@pytest.mark.slow
def test_dryrun_multipod_small_mesh(tmp_path):
    r = _run(
        ["-m", "repro.launch.dryrun", "--arch", "phi3-mini-3.8b",
         "--shape", "decode_32k", "--mesh", "multipod", "--out", str(tmp_path),
         "--no-correct"],
        env_extra={"REPRO_DRYRUN_DEVICES": "8", "REPRO_TEST_MESH": "2x2x2"},
    )
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-4000:]
    assert "[ok]" in r.stdout


@pytest.mark.slow
def test_train_driver_end_to_end(tmp_path):
    """launch.train runs a real (smoke-scale) HO-SGD training loop."""
    r = _run(
        ["-m", "repro.launch.train", "--arch", "gemma2-2b", "--reduce",
         "smoke", "--steps", "9", "--tau", "3", "--batch", "4", "--seq", "32",
         "--ckpt", str(tmp_path / "ck"), "--log", str(tmp_path / "log.csv")],
    )
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-4000:]
    assert "done; final loss" in r.stdout
    assert (tmp_path / "log.csv").exists()
    assert any(p.name.startswith("step_") for p in (tmp_path / "ck").iterdir())
