"""The use_pallas model path (interpret mode) equals the jnp path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T


@pytest.mark.parametrize("arch", ["qwen3-14b", "falcon-mamba-7b", "gemma2-2b"])
def test_pallas_forward_matches_jnp(arch):
    # kernel-aligned smoke shapes: S multiple of 64, d_inner multiple of 64
    cfg = get_config(arch).reduced().with_(remat=False, ssm_expand=2)
    if cfg.layer_pattern == "local_global":
        # mixed windows fall back to jnp; force the uniform-window variant
        cfg = cfg.with_(long_context=True)
    if cfg.has_ssm:
        cfg = cfg.with_(d_model=128)  # d_inner = 256, 64-aligned
    params = T.init_model(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    B, S = 2, 128
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    base, _ = T.forward_logits(cfg, params, {"tokens": toks})
    fast, _ = T.forward_logits(cfg.with_(use_pallas=True), params,
                               {"tokens": toks})
    np.testing.assert_allclose(np.asarray(fast), np.asarray(base),
                               rtol=3e-3, atol=3e-3)
