"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis isn't part of the pinned environment everywhere; skip (don't
# fail collection) when absent so tier-1 runs on the bare container image.
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import directions as D
from repro.core.baselines import quantize_qsgd
from repro.models import transformer as T
from repro.configs import get_config

SETTINGS = dict(max_examples=25, deadline=None)


@given(n=st.integers(1, 2048), salt=st.integers(0, 2**32 - 1),
       offset=st.integers(0, 2**20))
@settings(**SETTINGS)
def test_hash_gaussian_deterministic_and_finite(n, salt, offset):
    a = D.gaussian_from_salt((n,), np.uint32(salt), offset)
    b = D.gaussian_from_salt((n,), np.uint32(salt), offset)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert bool(jnp.all(jnp.isfinite(a)))
    assert float(jnp.max(jnp.abs(a))) < 7.0  # 24-bit Box-Muller tail bound


@given(n=st.integers(2, 512), split=st.integers(1, 511),
       salt=st.integers(0, 2**31))
@settings(**SETTINGS)
def test_hash_offset_additivity(n, split, salt):
    """Any split of a leaf generates identical values — the invariant that
    makes Pallas-block, per-shard, and whole-tree generation agree."""
    split = split % n or 1
    whole = np.asarray(D.gaussian_from_salt((n,), np.uint32(salt)))
    a = np.asarray(D.gaussian_from_salt((split,), np.uint32(salt), 0))
    b = np.asarray(D.gaussian_from_salt((n - split,), np.uint32(salt), split))
    np.testing.assert_array_equal(whole, np.concatenate([a, b]))


@given(shapes=st.lists(st.tuples(st.integers(1, 8), st.integers(1, 8)),
                       min_size=1, max_size=4),
       seed=st.integers(0, 1000), t=st.integers(0, 1000), w=st.integers(0, 64))
@settings(**SETTINGS)
def test_sphere_direction_always_unit(shapes, seed, t, w):
    params = {f"p{i}": jnp.zeros(s) for i, s in enumerate(shapes)}
    v = D.sphere_direction(params, seed, jnp.int32(t), jnp.uint32(w))
    ssq = sum(float(jnp.sum(x**2)) for x in jax.tree.leaves(v))
    assert abs(ssq - 1.0) < 1e-4


@given(s=st.integers(1, 64), scale=st.floats(0.1, 100.0),
       seed=st.integers(0, 100))
@settings(**SETTINGS)
def test_qsgd_preserves_sign_and_zero(s, scale, seed):
    g = jnp.asarray(np.random.default_rng(seed).normal(size=64) * scale,
                    jnp.float32)
    q = quantize_qsgd(g, s, jax.random.key(seed))
    assert bool(jnp.all((q == 0) | (jnp.sign(q) == jnp.sign(g))))
    assert bool(jnp.all(jnp.abs(q) <= jnp.linalg.norm(g) * (1 + 1e-5)))


@given(B=st.integers(1, 3), S=st.integers(2, 12), V=st.integers(8, 90),
       chunk=st.integers(3, 33), seed=st.integers(0, 50))
@settings(**SETTINGS)
def test_streaming_ce_equals_dense(B, S, V, chunk, seed):
    """The vocab-chunked CE is exactly the dense CE for any (V, chunk)."""
    cfg = get_config("phi3-mini-3.8b").reduced().with_(
        vocab_size=V, ce_chunk=chunk, n_layers=2)
    rng = np.random.default_rng(seed)
    head = jnp.asarray(rng.normal(size=(cfg.d_model, V)), jnp.float32)
    h = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)), jnp.float32)
    labels = jnp.asarray(rng.integers(-1, V, (B, S)), jnp.int32)
    got = T.cross_entropy_streaming(cfg, head, h, labels)
    want = T.cross_entropy(jnp.einsum("bsd,dv->bsv", h, head), labels)
    if bool(jnp.any(labels >= 0)):
        np.testing.assert_allclose(float(got), float(want), rtol=1e-4, atol=1e-5)


@given(seed=st.integers(0, 30), chunk=st.sampled_from([2, 3, 5, 8]))
@settings(max_examples=10, deadline=None)
def test_chunked_attention_equals_dense(seed, chunk):
    from repro.models import attention as A
    cfg = get_config("qwen3-14b").reduced().with_(attn_chunk=chunk, remat=False)
    p = A.init_attention(jax.random.key(seed), cfg, jnp.float32)
    x = jnp.asarray(np.random.default_rng(seed).normal(size=(2, 16, cfg.d_model)),
                    jnp.float32) * 0.1
    got = A.attention_forward(cfg, p, x, jnp.int32(1 << 30))
    want = A.attention_forward(cfg.with_(attn_chunk=0), p, x, jnp.int32(1 << 30))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)
