"""launch.xla: XLA_FLAGS composition — append, never clobber (the
launch/dryrun.py fix).  The pure string function carries the contract;
``append_xla_flags`` is pinned against a monkeypatched environment so the
dryrun's device-count override provably survives user-set overlap flags."""
import os

import pytest

from repro.launch.xla import (
    OVERLAP_FLAGS,
    append_xla_flags,
    compose_xla_flags,
    enable_collective_overlap,
)

USER = "--xla_gpu_enable_latency_hiding_scheduler=true --xla_dump_to=/tmp/d"


def test_compose_preserves_user_flags_in_order():
    out = compose_xla_flags(["--xla_force_host_platform_device_count=512"],
                            current=USER)
    assert out.split() == USER.split() + [
        "--xla_force_host_platform_device_count=512"]


def test_compose_drop_prefixes_replaces_owned_knob():
    """The dryrun owns the device-count knob: a stale value is dropped, the
    user's other flags survive untouched."""
    current = "--xla_force_host_platform_device_count=8 " + USER
    out = compose_xla_flags(["--xla_force_host_platform_device_count=512"],
                            current=current,
                            drop_prefixes=(
                                "--xla_force_host_platform_device_count",))
    assert out.split() == USER.split() + [
        "--xla_force_host_platform_device_count=512"]


def test_compose_dedupes_verbatim_and_handles_empty():
    assert compose_xla_flags(list(OVERLAP_FLAGS), current=USER).split() == \
        USER.split() + [f for f in OVERLAP_FLAGS if f not in USER.split()]
    assert compose_xla_flags(["--a=1"], current="") == "--a=1"
    assert compose_xla_flags([], current=USER) == USER


def test_append_composes_into_environment(monkeypatch):
    monkeypatch.setenv("XLA_FLAGS", USER)
    got = append_xla_flags(["--xla_force_host_platform_device_count=512"],
                           drop_prefixes=(
                               "--xla_force_host_platform_device_count",))
    assert os.environ["XLA_FLAGS"] == got
    assert got.startswith(USER)                       # user flags kept
    assert "--xla_force_host_platform_device_count=512" in got.split()


def test_append_from_unset_environment(monkeypatch):
    monkeypatch.delenv("XLA_FLAGS", raising=False)
    assert append_xla_flags(["--a=1"]) == "--a=1"
    assert os.environ["XLA_FLAGS"] == "--a=1"


def test_enable_collective_overlap_idempotent(monkeypatch):
    monkeypatch.setenv("XLA_FLAGS", "--xla_dump_to=/tmp/d")
    first = enable_collective_overlap()
    assert set(OVERLAP_FLAGS) <= set(first.split())
    assert "--xla_dump_to=/tmp/d" in first.split()
    assert enable_collective_overlap() == first       # no duplication


def test_dryrun_composes_instead_of_clobbering(monkeypatch):
    """The regression this PR fixes: importing launch.dryrun used to
    overwrite XLA_FLAGS wholesale; it must now preserve user flags while
    owning only the device-count knob."""
    import importlib
    import sys

    monkeypatch.setenv("XLA_FLAGS",
                       "--xla_force_host_platform_device_count=8 " + USER)
    monkeypatch.setenv("REPRO_DRYRUN_DEVICES", "16")
    # re-execute only the module-level env mutation; restore afterwards so
    # the already-imported jax backend state stays untouched elsewhere
    sys.modules.pop("repro.launch.dryrun_flags", None)
    importlib.import_module("repro.launch.dryrun_flags")
    flags = os.environ["XLA_FLAGS"].split()
    assert "--xla_force_host_platform_device_count=16" in flags
    assert "--xla_force_host_platform_device_count=8" not in flags
    for f in USER.split():
        assert f in flags
