# NOTE: no XLA_FLAGS here — smoke tests and benches must see 1 device.
# Device-count-dependent tests run the dry-run / distributed checks in
# subprocesses (see test_distributed.py) so this process stays single-device.
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
