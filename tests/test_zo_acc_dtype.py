"""Beyond-paper ZO knobs: bf16 reconstruction accumulator stays close to the
fp32 path (runs on a degenerate 1x1 mesh, no extra devices needed)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.configs import get_config
from repro.core.distributed import make_zo_step
from repro.core.ho_sgd import HOSGDConfig
from repro.launch.mesh import make_test_mesh
from repro.models import transformer as T
from repro.opt.optimizers import const_schedule, sgd


def test_bf16_accumulator_close_to_fp32():
    mesh = make_test_mesh(data=1, model=1)
    cfg = get_config("gemma2-2b").reduced()
    params = T.init_model(jax.random.key(0), cfg)
    loss_fn = lambda p, b: T.loss_fn(cfg, p, b)
    d = sum(x.size for x in jax.tree.leaves(params))
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (4, 16)).astype(np.int32)
    labels = np.concatenate([toks[:, 1:], -np.ones((4, 1), np.int32)], 1)
    batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}

    outs = {}
    with compat.set_mesh(mesh):
        for dt in ("float32", "bfloat16"):
            ho = HOSGDConfig(tau=1 << 30, mu=1e-3, m=1, lr=0.05,
                             zo_lr=0.05 / d, acc_dtype=dt)
            opt = sgd(const_schedule(ho.lr))
            zo = jax.jit(make_zo_step(loss_fn, mesh, ho, opt))
            p1, _, loss = zo(jnp.int32(3), params, opt.init(params), batch)
            outs[dt] = (jax.device_get(p1), float(loss))

    assert outs["float32"][1] == outs["bfloat16"][1]  # same loss eval
    # updates agree to bf16 resolution relative to the update magnitude
    for a, b, p0 in zip(jax.tree.leaves(outs["float32"][0]),
                        jax.tree.leaves(outs["bfloat16"][0]),
                        jax.tree.leaves(params)):
        upd = np.asarray(a, np.float32) - np.asarray(p0, np.float32)
        diff = np.abs(np.asarray(a, np.float32) - np.asarray(b, np.float32))
        scale = max(np.abs(upd).max(), 1e-12)
        assert diff.max() <= 0.02 * scale + 1e-7, (diff.max(), scale)
