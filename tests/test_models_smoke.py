"""Required per-arch smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and no NaNs; plus a decode step where the arch
has one."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import transformer as T
from repro.opt.optimizers import apply_deltas, const_schedule, sgd


def make_batch(cfg, B=2, S=16, rng=None):
    rng = rng or np.random.default_rng(0)
    if cfg.frontend == "audio":
        return {
            "features": jnp.asarray(rng.normal(size=(B, S, cfg.d_model)), jnp.float32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        }, S
    if cfg.frontend == "vision":
        P = cfg.n_patches
        toks = rng.integers(0, cfg.vocab_size, (B, S))
        labels = np.concatenate(
            [-np.ones((B, P), np.int32), toks[:, 1:], -np.ones((B, 1), np.int32)], 1)
        return {
            "tokens": jnp.asarray(toks, jnp.int32),
            "image_embeds": jnp.asarray(rng.normal(size=(B, P, cfg.d_model)), jnp.float32),
            "labels": jnp.asarray(labels, jnp.int32),
        }, S + P
    toks = rng.integers(0, cfg.vocab_size, (B, S))
    labels = np.concatenate([toks[:, 1:], -np.ones((B, 1), np.int32)], 1)
    return {
        "tokens": jnp.asarray(toks, jnp.int32),
        "labels": jnp.asarray(labels, jnp.int32),
    }, S


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    params = T.init_model(jax.random.key(0), cfg)
    batch, S_total = make_batch(cfg)
    B = 2

    logits, aux = jax.jit(lambda p, b: T.forward_logits(cfg, p, b))(params, batch)
    assert logits.shape == (B, S_total, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), arch
    assert bool(jnp.isfinite(aux))

    # one SGD train step
    opt = sgd(const_schedule(1e-2))

    @jax.jit
    def step(p, b):
        loss, g = jax.value_and_grad(lambda pp: T.loss_fn(cfg, pp, b))(p)
        deltas, _ = opt.update(g, opt.init(p), p, 0)
        return apply_deltas(p, deltas), loss

    p1, loss = step(params, batch)
    assert bool(jnp.isfinite(loss)), arch
    moved = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(params))
    )
    assert moved
    # a second step at the new point should also be finite
    _, loss2 = step(p1, batch)
    assert bool(jnp.isfinite(loss2))


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if not get_config(a).encoder_only])
def test_smoke_decode_step(arch):
    cfg = get_config(arch).reduced()
    params = T.init_model(jax.random.key(0), cfg)
    B, S = 2, 24
    caches = T.init_caches(cfg, B, S, jnp.float32)
    tok = jnp.zeros((B,), jnp.int32)
    logits, caches2 = jax.jit(
        lambda p, t, c: T.decode_step(cfg, p, t, jnp.int32(5), c)
    )(params, tok, caches)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), arch
    assert jax.tree.structure(caches2) == jax.tree.structure(caches)


@pytest.mark.parametrize("arch", ["gemma2-2b", "qwen3-14b", "falcon-mamba-7b",
                                  "hymba-1.5b"])
def test_decode_matches_forward(arch):
    """Greedy decode logits at position t must equal full-forward logits."""
    cfg = get_config(arch).reduced().with_(remat=False)
    params = T.init_model(jax.random.key(1), cfg)
    B, S = 2, 12
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    full_logits, _ = T.forward_logits(cfg, params, {"tokens": toks})

    caches = T.init_caches(cfg, B, S, jnp.float32)
    outs = []
    for t in range(S):
        lg, caches = T.decode_step(cfg, params, toks[:, t], jnp.int32(t), caches)
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec), np.asarray(full_logits), rtol=2e-3, atol=2e-3)


def test_prefill_matches_decode_continuation():
    cfg = get_config("qwen3-14b").reduced().with_(remat=False)
    params = T.init_model(jax.random.key(2), cfg)
    B, S = 2, 10
    rng = np.random.default_rng(5)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    last_logits, caches = T.prefill(cfg, params, {"tokens": toks})
    # same thing token by token
    caches2 = T.init_caches(cfg, B, S, jnp.float32)
    for t in range(S):
        lg, caches2 = T.decode_step(cfg, params, toks[:, t], jnp.int32(t), caches2)
    np.testing.assert_allclose(np.asarray(last_logits), np.asarray(lg),
                               rtol=2e-3, atol=2e-3)
