"""Baseline methods: semantics and sanity convergence."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    HOSGDConfig, make_ho_sgd, make_pa_sgd, make_qsgd, make_ri_sgd,
    make_sync_sgd, make_zo_svrg_ave, run_method,
)
from repro.core.baselines import quantize_qsgd, ri_shard_batch


def quad_loss(params, batch):
    return 0.5 * jnp.mean(jnp.sum((params["x"] - batch["t"]) ** 2, -1))


def quad_batches(m, B, d, seed=0):
    rng = np.random.default_rng(seed)
    while True:
        yield {"t": (1.0 + 0.1 * rng.normal(size=(m * B, d))).astype(np.float32)}


D_ = 32
P0 = {"x": jnp.zeros((D_,))}


def gap(hist):
    return float(quad_loss(hist["params"], {"t": np.ones((1, D_), np.float32)}))


def test_pa_sgd_tau1_equals_sync():
    """Averaging every step == synchronous SGD (same gradients, same lr)."""
    m, B = 4, 8
    pa = make_pa_sgd(quad_loss, m, tau=1, lr=0.3)
    sy = make_sync_sgd(quad_loss, m, lr=0.3)
    h1 = run_method(pa, P0, quad_batches(m, B, D_), 15)
    h2 = run_method(sy, P0, quad_batches(m, B, D_), 15)
    np.testing.assert_allclose(np.asarray(h1["params"]["x"]),
                               np.asarray(h2["params"]["x"]), rtol=1e-5)


def test_pa_sgd_converges_and_comm_model():
    m = 4
    pa = make_pa_sgd(quad_loss, m, tau=8, lr=0.3)
    assert gap(run_method(pa, P0, quad_batches(m, 8, D_), 100)) < 0.02
    assert pa.comm_scalars(1000) == 1000 / 8


def test_ri_sgd_runs_and_mixes():
    m = 4
    ri = make_ri_sgd(quad_loss, m, tau=4, lr=0.3, mu_r=0.25)
    assert gap(run_method(ri, P0, quad_batches(m, 8, D_), 80,
                          key=jax.random.key(0))) < 0.05
    batch = {"t": np.arange(32, dtype=np.float32).reshape(32, 1).repeat(D_, 1)}
    mixed = ri_shard_batch(batch, m, 0.25, jax.random.key(1))
    assert mixed["t"].shape == batch["t"].shape
    assert bool(jnp.any(mixed["t"] != jnp.asarray(batch["t"])))


def test_ri_sgd_zero_redundancy_is_pa():
    batch = {"t": np.ones((16, D_), np.float32)}
    out = ri_shard_batch(batch, 4, 0.0, jax.random.key(0))
    np.testing.assert_array_equal(np.asarray(out["t"]), batch["t"])


def test_zo_svrg_ave_descends():
    # ZO estimates scale with d: lr must be ~ lr_fo/d for stability
    m = 4
    dataset = {"t": np.ones((64, D_), np.float32)}
    meth = make_zo_svrg_ave(quad_loss, m, mu=1e-3, lr=0.06 / D_,
                            dataset=dataset, epoch_len=25)
    hist = run_method(meth, {"x": jnp.full((D_,), 3.0)},
                      quad_batches(m, 8, D_), 150)
    assert gap(hist) < 0.7 * gap({"params": {"x": jnp.full((D_,), 3.0)}})


def test_qsgd_quantizer_unbiased_and_bounded():
    g = jax.random.normal(jax.random.key(0), (512,))
    keys = jax.random.split(jax.random.key(1), 512)
    qs = jax.vmap(lambda k: quantize_qsgd(g, 8, k))(keys)
    err = jnp.mean(qs, 0) - g
    # unbiased: the MEAN error is MC noise ~ (||g||/s)/sqrt(512) per element
    assert float(jnp.mean(jnp.abs(err))) < 0.06
    assert float(jnp.max(jnp.abs(err))) < 0.4
    # quantized values live on the s-level grid scaled by ||g||
    q = qs[0]
    lv = jnp.abs(q) / jnp.linalg.norm(g) * 8
    assert float(jnp.max(jnp.abs(lv - jnp.round(lv)))) < 1e-4


def test_qsgd_converges():
    m = 4
    meth = make_qsgd(quad_loss, m, s=8, lr=0.3)
    assert gap(run_method(meth, P0, quad_batches(m, 8, D_), 80,
                          key=jax.random.key(2))) < 0.05
