"""Federated partial-participation suite (ISSUE 9 acceptance).

Pins the four federated contracts:

* cohort determinism — the seeded K-of-N schedule (``ClientSampling``)
  redraws bit-identical cohorts per (spec, t), bounds them correctly, and
  availability churn keeps at least one survivor;
* ledger pins — a ``masked_average`` round books per-client payload bytes
  × |live cohort| (codec ∈ {none, qsgd}), never × N, identically in
  ``metrics["comm_bytes"]`` and through a wrapped ``CommLedger``;
* masked-average weighting — the FedDropoutAvg closed form (weight =
  nonzero-mask × client dataset size, absent coordinates keep the server
  value);
* trajectory divergence — 1% participation genuinely diverges from full
  participation, and the sim trace stays bit-identical per seed.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import rounds as R
from repro.core.federated import (
    ClientSampling, cohort_shards, fed_avg_program,
)
from repro.dist import CommLedger
from repro.dist.collectives import _tree_nbytes
from repro.dist.compress import qsgd

D, K, N = 24, 4, 64


def quad_loss(params, batch):
    return 0.5 * jnp.mean(jnp.sum((params["x"] - batch["t"]) ** 2, -1))


def problem(rows=4 * K):
    params = {"x": jnp.linspace(-1.0, 1.0, D, dtype=jnp.float32)}
    batch = {"t": jnp.asarray(
        np.random.default_rng(0).normal(size=(rows, D)), jnp.float32)}
    return params, batch


def spec(**kw):
    kw.setdefault("n_clients", N)
    kw.setdefault("cohort_k", K)
    kw.setdefault("seed", 0)
    return ClientSampling(**kw)


# --------------------------------------------------------------------------- #
# cohort determinism
# --------------------------------------------------------------------------- #
def test_cohort_schedule_is_seeded_and_bounded():
    cs = spec(availability=0.8)
    for t in range(20):
        c = cs.cohort_for(t)
        assert c == cs.cohort_for(t)                  # bit-identical redraw
        assert 1 <= len(c) <= K                       # churn, >= 1 survivor
        assert all(0 <= i < N for i in c)
        assert list(c) == sorted(set(c))              # sorted, no repeats
    # full availability: exactly K distinct clients every round
    full = spec(availability=1.0)
    assert all(len(full.cohort_for(t)) == K for t in range(20))
    # the schedule actually varies over t and over seeds
    assert len({full.cohort_for(t) for t in range(20)}) > 1
    assert spec(seed=1).cohort_for(3) != spec(seed=2).cohort_for(3)


def test_client_sizes_fixed_positive_and_seeded():
    cs = spec()
    sizes = cs.client_sizes()
    assert sizes.shape == (N,) and (sizes >= 1).all()
    assert np.array_equal(sizes, cs.client_sizes())
    assert not np.array_equal(sizes, spec(seed=7).client_sizes())
    w = cs.client_weights([3, 11])
    assert np.array_equal(w, sizes[[3, 11]].astype(np.float64))


def test_cohort_shards_are_identity_keyed():
    """Client c's shard depends on (c, t) only — not on cohort position."""
    cs = spec()
    _, batch = problem()
    a = cohort_shards(batch, [3, 9], 5, cs)
    b = cohort_shards(batch, [9, 50], 5, cs)
    assert jnp.array_equal(a["t"][1], b["t"][0])      # client 9 either way
    c = cohort_shards(batch, [9], 6, cs)
    assert not jnp.array_equal(b["t"][0], c["t"][0])  # but varies with t
    assert a["t"].shape == (2, batch["t"].shape[0] // K, D)


# --------------------------------------------------------------------------- #
# masked-average closed form
# --------------------------------------------------------------------------- #
def test_masked_average_closed_form():
    stacked = {"a": jnp.asarray([[2.0, 0.0, 0.0],
                                 [4.0, 4.0, 0.0]], jnp.float32)}
    avg, wsum = R.masked_average(stacked, [1.0, 3.0])
    # coord 0: both sent -> (1*2 + 3*4) / (1+3); coord 1: only client 1
    # (weight 3) sent -> 4; coord 2: nobody sent -> avg 0, wsum 0
    np.testing.assert_allclose(np.asarray(avg["a"]), [3.5, 4.0, 0.0])
    np.testing.assert_allclose(np.asarray(wsum["a"]), [4.0, 3.0, 0.0])


def test_fed_avg_apply_keeps_server_value_where_nobody_sent():
    """lr=0 + full dropout survivors: masked average of identical models is
    the model; a coordinate every client dropped keeps the server value."""
    params, batch = problem()
    prog = fed_avg_program(quad_loss, spec(), lr=0.0, local_steps=2)
    ex = R.RoundExecutor(prog)
    p2, _, met = ex.run(0, params, prog.init(params), batch)
    # lr=0, no dropout: every client uploads the unchanged model, the
    # masked average reproduces it exactly
    np.testing.assert_allclose(np.asarray(p2["x"]), np.asarray(params["x"]),
                               rtol=1e-6)
    assert met["n_live"] == K


def test_masked_average_round_rejects_legacy_wire():
    noop = lambda *a: None
    with pytest.raises(AssertionError, match="per-client"):
        R.Round("f", 1, "masked_average", noop, noop,
                wire=R.Wire(qsgd(8), "legacy"))


# --------------------------------------------------------------------------- #
# ledger pins: bytes = per-client payload x |live cohort|, never x N
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("codec", [None, qsgd(8)])
def test_cohort_bytes_booked_per_live_client(codec):
    params, batch = problem()
    cs = spec(availability=0.75, seed=3)
    wire = R.Wire(codec) if codec is not None else None
    prog = fed_avg_program(quad_loss, cs, lr=0.05, local_steps=2, wire=wire)
    ex = R.RoundExecutor(prog)
    ledger = CommLedger()
    run = ledger.wrap("fed", lambda *a, **k: ex.run(*a, **k))
    state = prog.init(params)
    for t in range(4):
        live = len(cs.cohort_for(t))
        per = (_tree_nbytes(params) if codec is None
               else codec.nbytes(D))
        params, state, met = run(t, params, state, batch)
        assert met["n_live"] == live
        assert met["comm_bytes"] == per * live            # x |cohort|
        assert ledger.bytes_per_step("fed") == per * live  # ledger-identical
        assert met["comm_bytes"] < per * N                # never x N


def test_fed_ho_zo_round_books_4_bytes_per_live_client():
    from repro.core.ho_sgd import HOSGDConfig

    params, batch = problem()
    cs = spec(availability=0.75, seed=3)
    ho = HOSGDConfig(tau=4, mu=1e-3, m=K, lr=0.05, zo_lr=0.01, seed=0)
    prog = R.ho_sgd_program(quad_loss, ho, client_sampling=cs)
    ex = R.RoundExecutor(prog)
    state = prog.init(params)
    p = params
    for t in range(4):
        p, state, met = ex.run(t, p, state, batch)
        if met["order"] == 0:   # ZO: one fp32 coefficient per live client
            assert met["comm_bytes"] == 4 * met["n_live"]
            assert met["n_live"] == len(cs.cohort_for(t))


# --------------------------------------------------------------------------- #
# sim replay: determinism + participation divergence
# --------------------------------------------------------------------------- #
def _sim(cluster, method, iters=8, seed=0, tau=2):
    from repro.data.synthetic import batches, make_classification
    from repro.models.mlp import init_mlp_classifier, mlp_loss
    from repro.sim import compute_model_for, make_sim_methods, simulate

    ds = make_classification("acoustic", seed=0)
    params = init_mlp_classifier(jax.random.key(0), ds.n_features,
                                 ds.n_classes, hidden=8)
    batch = cluster.m * 4
    sm = make_sim_methods(mlp_loss, params, cluster, tau=tau, lr=0.05,
                          seed=seed, local_steps=2, which=[method])[method]
    compute = compute_model_for(params, cluster, batch // cluster.m)
    return simulate(sm, params, batches(ds, batch, seed=0), cluster, iters,
                    compute=compute)


@pytest.mark.parametrize("method", ["fed_ho_sgd", "fed_avg",
                                    "fed_dropout_avg"])
def test_federated_sim_trace_bit_identical_per_seed(method):
    from repro.sim import ClusterSpec

    cl = ClusterSpec(m=K, flops_per_sec=1e9, alpha=1e-5, bandwidth=1e6,
                     n_clients=N, cohort_k=K, availability=0.8, seed=0)
    r1, r2 = _sim(cl, method), _sim(cl, method)
    assert r1.trace == r2.trace
    assert r1.losses == r2.losses and r1.comm_bytes == r2.comm_bytes
    # a different cluster seed draws different cohorts -> different rounds
    r3 = _sim(cl.with_(seed=1), method)
    assert r3.losses != r1.losses or r3.comm_bytes != r1.comm_bytes


def test_participation_divergence_1pct_vs_full():
    """Sampling is not a repricing: a 2-of-64 cohort run genuinely diverges
    from full participation (same data stream, same method, same seed)."""
    from repro.sim import ClusterSpec

    full = ClusterSpec(m=8, flops_per_sec=1e9, alpha=1e-5, bandwidth=1e6,
                       n_clients=8, cohort_k=8, availability=1.0, seed=0)
    part = ClusterSpec(m=2, flops_per_sec=1e9, alpha=1e-5, bandwidth=1e6,
                       n_clients=64, cohort_k=2, availability=1.0, seed=0)
    rf, rp = _sim(full, "fed_avg"), _sim(part, "fed_avg")
    assert rf.losses != rp.losses
    # and the partial run's bytes follow the small cohort
    assert max(rp.active_counts) <= 2 < min(rf.active_counts)


def test_federated_cluster_spec_validation():
    from repro.sim import ClusterSpec

    with pytest.raises(AssertionError):   # m must equal cohort_k
        ClusterSpec(m=4, n_clients=64, cohort_k=8)
    with pytest.raises(AssertionError):   # cohort needs a population
        ClusterSpec(m=4, cohort_k=4)
    with pytest.raises(AssertionError):   # availability in (0, 1]
        ClusterSpec(m=4, n_clients=64, cohort_k=4, availability=0.0)
    with pytest.raises(AssertionError):   # server-synchronous only
        ClusterSpec(m=4, n_clients=64, cohort_k=4, max_staleness=2)
    cl = ClusterSpec(m=4, n_clients=64, cohort_k=4, availability=0.9, seed=5)
    cs = cl.sampling
    assert (cs.n_clients, cs.cohort_k, cs.seed, cs.availability) == \
        (64, 4, 5, 0.9)
    assert ClusterSpec(m=4).sampling is None


def test_topology_ceil_splits_non_divisible_membership():
    """Sampled cohorts are not pod-divisible: workers_per_pod prices the
    ceil split (like CollectiveModel.time_components) instead of aborting."""
    from repro.sim import ClusterSpec, Topology

    topo = Topology(pods=2)
    assert topo.workers_per_pod(5) == 3
    assert topo.workers_per_pod(4) == 2
    assert topo.workers_per_pod(1) == 1
    # a 2-pod cluster with an odd membership now constructs and prices
    cl = ClusterSpec(m=5, topology=topo)
    assert cl.collective_time(1024, w=3) > 0.0
