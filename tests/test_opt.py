"""Optimizer substrate: SGD/momentum/Adam + schedules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.opt.optimizers import (
    adam, apply_deltas, const_schedule, cosine_schedule, invsqrt_schedule,
    sgd, theorem_lr,
)


def rosenbrock(p):
    x, y = p["x"], p["y"]
    return (1 - x) ** 2 + 100 * (y - x**2) ** 2


def run(opt, p0, steps):
    state = opt.init(p0)
    p = p0
    for t in range(steps):
        g = jax.grad(rosenbrock)(p)
        d, state = opt.update(g, state, p, t)
        p = apply_deltas(p, d)
    return p


def test_sgd_descends_quadratic():
    f = lambda p: jnp.sum((p["x"] - 3.0) ** 2)
    p = {"x": jnp.zeros((4,))}
    opt = sgd(const_schedule(0.1))
    s = opt.init(p)
    for t in range(50):
        d, s = opt.update(jax.grad(f)(p), s, p, t)
        p = apply_deltas(p, d)
    np.testing.assert_allclose(np.asarray(p["x"]), 3.0, atol=1e-3)


def test_momentum_accelerates():
    p0 = {"x": jnp.float32(-1.0), "y": jnp.float32(1.0)}
    plain = run(sgd(const_schedule(1e-3)), p0, 300)
    mom = run(sgd(const_schedule(1e-3), momentum=0.9), p0, 300)
    assert float(rosenbrock(mom)) < float(rosenbrock(plain))


def test_adam_converges_rosenbrock():
    p0 = {"x": jnp.float32(-1.0), "y": jnp.float32(1.0)}
    p = run(adam(const_schedule(0.05)), p0, 500)
    assert float(rosenbrock(p)) < 0.1


def test_schedules():
    s = invsqrt_schedule(1.0, warmup=0)
    assert float(s(0)) == pytest.approx(1.0)
    assert float(s(99)) == pytest.approx(0.1, rel=0.1)
    c = cosine_schedule(1.0, total=100, warmup=10)
    assert float(c(0)) == pytest.approx(0.0)
    assert float(c(10)) == pytest.approx(1.0)
    assert float(c(100)) == pytest.approx(0.1, rel=1e-3)  # floor
    assert theorem_lr(B=5, m=5, N=100, L=1.0) == pytest.approx(0.5)


def test_adam_bias_correction_first_step():
    """After one step from zeros-init moments, update ~= -lr * sign(g)."""
    opt = adam(const_schedule(0.1))
    p = {"x": jnp.zeros((3,))}
    g = {"x": jnp.asarray([1.0, -2.0, 0.5])}
    d, _ = opt.update(g, opt.init(p), p, 0)
    np.testing.assert_allclose(np.asarray(d["x"]),
                               [-0.1, 0.1, -0.1], rtol=1e-4)
