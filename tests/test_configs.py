import pytest

from repro.configs import ARCH_IDS, SHAPES, all_configs, get_config, shape_applicable
from repro.configs.base import config_for_shape


def test_registry_complete():
    assert len(ARCH_IDS) == 10
    cfgs = all_configs()
    assert {c.arch_type for c in cfgs.values()} == {
        "dense", "moe", "ssm", "hybrid", "vlm", "audio"
    }
    for c in cfgs.values():
        assert c.source, f"{c.name} must cite its source"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_published_dims(arch):
    c = get_config(arch)
    assert c.param_count() > 0
    if c.has_attention:
        assert c.n_heads % c.n_kv_heads == 0
    assert len(c.layer_windows()) == c.n_layers
    assert c.n_layers % c.pattern_period == 0


def test_exact_assigned_dims():
    c = get_config("arctic-480b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads) == (35, 7168, 56, 8)
    assert (c.n_experts, c.top_k, c.d_ff, c.vocab_size) == (128, 2, 4864, 32000)
    q = get_config("qwen3-moe-235b-a22b")
    assert (q.n_layers, q.d_model, q.top_k) == (94, 4096, 8)
    f = get_config("falcon-mamba-7b")
    assert (f.n_layers, f.d_model, f.ssm_state, f.d_ff) == (64, 4096, 16, 0)
    h = get_config("hymba-1.5b")
    assert (h.n_layers, h.d_model, h.n_heads, h.n_kv_heads) == (32, 1600, 25, 5)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_constraints(arch):
    r = get_config(arch).reduced()
    assert r.n_layers <= 4 and r.d_model <= 512 and r.n_experts <= 4


def test_param_counts_match_scale():
    # sanity: published total params within 2x of the name-plate number
    expect = {
        "phi3-mini-3.8b": 3.8e9, "gemma2-2b": 2.6e9, "falcon-mamba-7b": 7.3e9,
        "starcoder2-3b": 3.0e9, "qwen3-14b": 14.8e9, "pixtral-12b": 12.4e9,
        "hymba-1.5b": 1.5e9, "hubert-xlarge": 0.96e9, "arctic-480b": 482e9,
        "qwen3-moe-235b-a22b": 235e9,
    }
    for arch, e in expect.items():
        n = get_config(arch).param_count()
        assert 0.5 * e < n < 2.0 * e, (arch, n, e)


def test_shape_skip_rules():
    # encoder-only: no decode
    hub = get_config("hubert-xlarge")
    assert not shape_applicable(hub, SHAPES["decode_32k"])[0]
    assert not shape_applicable(hub, SHAPES["long_500k"])[0]
    assert shape_applicable(hub, SHAPES["prefill_32k"])[0]
    # pure full attention: no long_500k
    for a in ("phi3-mini-3.8b", "qwen3-14b", "arctic-480b", "pixtral-12b",
              "qwen3-moe-235b-a22b"):
        assert not shape_applicable(get_config(a), SHAPES["long_500k"])[0], a
    # ssm / hybrid / swa variants run long_500k
    for a in ("falcon-mamba-7b", "hymba-1.5b", "gemma2-2b", "starcoder2-3b"):
        assert shape_applicable(get_config(a), SHAPES["long_500k"])[0], a


def test_long_context_variant_is_subquadratic():
    for a in ("gemma2-2b", "starcoder2-3b", "hymba-1.5b"):
        c = config_for_shape(get_config(a), SHAPES["long_500k"])
        assert c.subquadratic, a


def test_expected_pair_count():
    n_ok = sum(
        shape_applicable(get_config(a), s)[0]
        for a in ARCH_IDS for s in SHAPES.values()
    )
    # 40 pairs - 7 documented skips (hubert x2 decode shapes; long_500k for
    # the five pure-full-attention archs: phi3, pixtral, arctic, qwen3-14b,
    # qwen3-moe)
    assert n_ok == 33
